#!/usr/bin/env bash
# Tier-1 verification: the repo's own test suite on CPU.
# Usage: scripts/tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
exec python -m pytest -x -q "$@"
