"""Render the §Dry-run / §Roofline tables for EXPERIMENTS.md from
experiments/dryrun/*.json.

Run: PYTHONPATH=src python -m benchmarks.roofline_table [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HERE = os.path.dirname(__file__)
DRY = os.path.join(HERE, "..", "experiments", "dryrun")

ARCH_ORDER = ["mistral-nemo-12b", "falcon-mamba-7b", "recurrentgemma-9b",
              "yi-6b", "phi-3-vision-4.2b", "whisper-large-v3",
              "smollm-135m", "llama4-scout-17b-a16e", "deepseek-v2-236b",
              "qwen3-32b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = ""):
    out = {}
    suffix = f"__{tag}" if tag else ""
    for p in glob.glob(os.path.join(DRY, f"*__{mesh}{suffix}.json")):
        base = os.path.basename(p)[:-5]
        parts = base.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) != 3:
            continue
        rec = json.load(open(p))
        out[(rec["arch"], rec["shape"])] = rec
    return out


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def render(mesh: str, tag: str = "") -> str:
    recs = load(mesh, tag)
    lines = [
        f"### Roofline — {mesh} mesh"
        + (f" [{tag}]" if tag else "")
        + " (per step; ms on TPU v5e terms)",
        "",
        "| arch | shape | compute | memory | collective | dominant |"
        " useful FLOP ratio | note |",
        "|---|---|---:|---:|---:|---|---:|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None:
                lines.append(f"| {arch} | {shape} | - | - | - | MISSING | |")
                continue
            if rec["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | skipped |"
                             f" | {rec['reason'][:60]} |")
                continue
            if rec["status"] == "error":
                lines.append(f"| {arch} | {shape} | - | - | - | ERROR | |"
                             f" {rec['error'][:60]} |")
                continue
            r = rec["roofline"]
            fb = len(rec.get("fallbacks", []))
            note = f"{fb} repl-fallbacks" if fb else ""
            lines.append(
                f"| {arch} | {shape} | {fmt_ms(r['compute_s'])} |"
                f" {fmt_ms(r['memory_s'])} | {fmt_ms(r['collective_s'])} |"
                f" {r['dominant']} | {r['useful_flops_ratio']:.2f} |"
                f" {note} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(render(args.mesh, args.tag))


if __name__ == "__main__":
    main()
