"""Round-latency benchmark: sequential per-node loop vs node-stacked engine,
width-bucketed vs pad-to-max-width layouts, fused multi-round blocks, and
the server-step Gram backend.

The sequential reference dispatches one jitted step per node per local step
(K x E per round) and tokenizes each batch eagerly on the host; the engine
runs the whole round — vmapped local epochs per width bucket + the server
step — as ONE compiled call with donated round-state buffers.  This bench
measures wall-clock per round for both at K in {4, 8, 16} and writes
``BENCH_federation.json``.

The K sweep uses the image+text modality pair; the ``mixed_width`` row runs
the full 4-modality mix (192..2048-dim tokenizers) and compares the legacy
single-bucket layout (every node padded to 2048, narrow nodes paying the
quadratic w^2 padding tax) against width bucketing, which groups nodes by
tokenizer width inside the same single-dispatch round.  A peak-memory
column (XLA ``memory_analysis`` on the compiled round) reports the
round-state donation savings: donated buffers alias outputs onto inputs,
so peak round-state memory stays ~1x instead of 2x.

``fused_rounds_m{M}`` rows measure the block executor (``run_block``:
lax.scan over M whole rounds, donated carry) against the per-round engine:
ms/round, dispatches and blocking host syncs per round (both 1/M fused),
and the compiled block's peak bytes.  ``sampled_cohort_*`` / ``dropout_*``
rows measure partial participation through the fused blocks: a uniform
C-of-K cohort must cost ~C/K of the full round (the gather-compact path)
and a dropout straggler mask ~1x (masked path), both still at 1/M
dispatches per round.  The ``gram_backend`` row compares the reference jnp
Gram against the Pallas kernel (interpret mode on CPU — the
dispatch-correctness datapoint; the performance target is TPU) on the
server step.

``async_lagged_k{K}`` / ``quarantine_1_poisoned`` rows measure the
buffered staleness-aware protocol through the fused blocks: rounds/sec vs
the synchronous baseline, the staleness histogram of delivered reports,
the device quarantine counters against an independent host-side count of
poisoned report attempts, a finite-globals check, and the final
cross-node CKA convergence proxy — all still at one measured dispatch per
M-round block.

Run: PYTHONPATH=src python -m benchmarks.federation_round [--quick|--smoke]
(``--only SUBSTR`` re-runs just the matching rows and merges them into
the existing JSON.)
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.configs import get_config
from repro.core.federation import (Federation, FederationConfig,
                                   SequentialFederation)

TINY = get_config("fedmm-small").with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32")

LOCAL_STEPS = 4
MIXED_MODALITIES = ("image", "text", "genetics", "tabular")


def _fedcfg(k: int, modalities) -> FederationConfig:
    return FederationConfig(n_nodes=k, rounds=1, local_steps=LOCAL_STEPS,
                            local_batch=8, method="geolora", lora_rank=4,
                            anchors_per_class=2, n_tokens=4,
                            modalities=modalities)


def _light_fedcfg(k: int, modalities) -> FederationConfig:
    """The high-round-rate regime (small batches, tiny anchor set) shared
    by the fused-rounds and participation rows, so their ms/round numbers
    stay comparable in BENCH_federation.json."""
    return FederationConfig(n_nodes=k, rounds=1, local_steps=LOCAL_STEPS,
                            local_batch=4, method="geolora", lora_rank=2,
                            anchors_per_class=1, n_tokens=2,
                            modalities=modalities)


def _time_rounds(f, rounds: int) -> float:
    """Best-of-N ms/round (min is the robust latency estimator under CPU
    contention; the first round is warmup and pays compilation)."""
    f.run_round()
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        f.run_round()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _peak_bytes(f: Federation, block_m: int = None) -> int:
    """Estimated peak live bytes of one compiled round (or, with
    ``block_m``, one fused M-round block): arguments + outputs + XLA
    temporaries, minus the donated input/output aliases."""
    args = (f._trains, f._opts, f._keys, f.gbar, f._server_m, f._staticss,
            (None,) * len(f._trains))
    fn = f.engine.round_fn if block_m is None else f.engine.block_fn(block_m)
    ma = fn.lower(*args).compile().memory_analysis()
    return int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)



def _count_calls(holder, key=None, attr=None):
    """Wrap a compiled engine function with a dispatch counter so the
    bench MEASURES the dispatch structure it reports (and CI guards)
    instead of asserting a constant.  ``holder`` is either the engine's
    ``_block_cache`` dict (pass ``key``) or the engine itself (pass
    ``attr`` for the per-round ``round_fn``)."""
    calls = {"n": 0}
    orig = holder[key] if attr is None else getattr(holder, attr)

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    if attr is None:
        holder[key] = counting
    else:
        setattr(holder, attr, counting)
    return calls


def bench_cfg(name: str, k: int, modalities, rounds: int) -> dict:
    fedcfg = _fedcfg(k, modalities)
    seq_ms = _time_rounds(SequentialFederation(fedcfg, TINY), rounds)
    eng_ms = _time_rounds(Federation(fedcfg, TINY), rounds)
    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "sequential_ms_per_round": round(seq_ms, 2),
        "engine_ms_per_round": round(eng_ms, 2),
        "speedup": round(seq_ms / eng_ms, 2),
        # dispatch structure: the loop issues one jitted call per node per
        # local step; the engine compiles the whole round into one call
        "sequential_dispatches_per_round": k * LOCAL_STEPS,
        "engine_dispatches_per_round": 1,
    }
    print(f"{name} K={k}: sequential={seq_ms:.1f}ms "
          f"engine={eng_ms:.1f}ms speedup={row['speedup']}x", flush=True)
    return row


def bench_mixed_bucketed(name: str, k: int, modalities, rounds: int) -> dict:
    """Padded (single-bucket, pad-to-max-width) vs width-bucketed engine on
    a heterogeneous-width modality mix, plus the donation memory column."""
    fedcfg = _fedcfg(k, modalities)
    seq_ms = _time_rounds(SequentialFederation(fedcfg, TINY), rounds)

    padded = Federation(fedcfg, TINY, width_bucketing=False)
    padded_peak = _peak_bytes(padded)
    padded_ms = _time_rounds(padded, rounds)

    bucketed = Federation(fedcfg, TINY)
    bucketed_peak = _peak_bytes(bucketed)
    no_donate_peak = _peak_bytes(Federation(fedcfg, TINY, donate=False))
    bucketed_ms = _time_rounds(bucketed, rounds)

    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "bucket_widths": list(bucketed._bucket_widths),
        "sequential_ms_per_round": round(seq_ms, 2),
        "padded_engine_ms_per_round": round(padded_ms, 2),
        "engine_ms_per_round": round(bucketed_ms, 2),
        "speedup": round(seq_ms / bucketed_ms, 2),
        "padded_speedup": round(seq_ms / padded_ms, 2),
        "bucketed_vs_padded": round(padded_ms / bucketed_ms, 2),
        "sequential_dispatches_per_round": k * LOCAL_STEPS,
        "engine_dispatches_per_round": 1,
        # donation column: peak live bytes of the compiled round
        "peak_bytes_donated": bucketed_peak,
        "peak_bytes_no_donation": no_donate_peak,
        "donation_saved_bytes": no_donate_peak - bucketed_peak,
        "padded_peak_bytes_donated": padded_peak,
    }
    print(f"{name} K={k}: sequential={seq_ms:.1f}ms padded={padded_ms:.1f}ms "
          f"bucketed={bucketed_ms:.1f}ms "
          f"(bucketed vs padded {row['bucketed_vs_padded']}x, "
          f"vs sequential {row['speedup']}x) "
          f"peak {bucketed_peak/1e6:.1f}MB donated vs "
          f"{no_donate_peak/1e6:.1f}MB undonated", flush=True)
    return row


def bench_fused_rounds(name: str, k: int, modalities, reps: int,
                       m: int) -> dict:
    """Per-round engine (1 dispatch + 1 blocking host sync per round) vs
    the fused M-round block executor (1 donated dispatch + 1 sync per M
    rounds: lax.scan over the round body, metrics in (M, ...) buffers).

    Uses a light round config (the high-round-rate regime the fusion
    targets, where the host round-trip is a visible slice of the round)
    and INTERLEAVES the two timings rep by rep so slow machine-load drift
    cancels instead of biasing whichever variant ran later."""
    fedcfg = _light_fedcfg(k, modalities)
    per_round = Federation(fedcfg, TINY)
    fused = Federation(fedcfg, TINY)
    per_round_peak = _peak_bytes(per_round)
    fused_peak = _peak_bytes(fused, block_m=m)
    for _ in range(m):                     # warmup + compile both variants
        per_round.run_round()
    fused.run_rounds(m, block_size=m)
    # dispatch counters wrap the already-compiled functions AFTER warmup,
    # so the timed reps below measure the real dispatch structure
    pr_calls = _count_calls(per_round.engine, attr="round_fn")
    fu_calls = _count_calls(fused.engine._block_cache,
                            key=(m, False, None, False, 0))
    best_r = best_f = float("inf")
    # small M means short timed spans; take more reps so a transient
    # contention burst cannot bias a whole variant
    reps = max(reps, 32 // m)
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(m):
            per_round.run_round()
        best_r = min(best_r, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fused.run_rounds(m, block_size=m)
        best_f = min(best_f, time.perf_counter() - t0)
    per_round_ms = best_r / m * 1e3
    fused_ms = best_f / m * 1e3
    timed_rounds = reps * m

    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "block_rounds": m,
        "per_round_engine_ms_per_round": round(per_round_ms, 2),
        "fused_ms_per_round": round(fused_ms, 2),
        "fused_speedup": round(per_round_ms / fused_ms, 2),
        # dispatch structure, MEASURED over the timed reps (counters on
        # the compiled functions): the per-round driver issues one jitted
        # call per round; the block executor amortises it over M rounds.
        # Host syncs mirror the dispatch structure by construction (one
        # blocking metric readback per dispatch in both drivers).
        "dispatches_per_round": round(fu_calls["n"] / timed_rounds, 4),
        "host_syncs_per_round": round(1.0 / m, 4),
        "per_round_dispatches_per_round": round(
            pr_calls["n"] / timed_rounds, 4),
        "per_round_host_syncs_per_round": 1,
        "peak_bytes_per_round_engine": per_round_peak,
        "peak_bytes_fused_block": fused_peak,
    }
    print(f"{name} K={k} M={m}: per-round={per_round_ms:.1f}ms "
          f"fused={fused_ms:.1f}ms/round "
          f"(x{row['fused_speedup']}, dispatches/round 1 -> 1/{m}) "
          f"peak {fused_peak/1e6:.1f}MB vs {per_round_peak/1e6:.1f}MB",
          flush=True)
    return row


def bench_participation(name: str, k: int, modalities, reps: int, m: int,
                        plan) -> dict:
    """Partial participation through the fused-block executor: full
    participation vs a sampled cohort (gather-compact: local-epoch compute
    scales with the cohort size C, not K) or a dropout straggler mask
    (masked path: full compute, masked updates), all at 1/M dispatches and
    host syncs per round.  Interleaved best-of timing, same protocol as
    the fused-rounds bench."""
    fedcfg = _light_fedcfg(k, modalities)
    full = Federation(fedcfg, TINY)
    samp = Federation(fedcfg, TINY)
    full.run_rounds(m, block_size=m)                   # warmup + compile
    recs = samp.run_rounds(m, block_size=m, participation=plan)
    # measure the dispatch structure (counter on the compiled block fn,
    # installed after warmup): participation must not add dispatches
    samp_calls = _count_calls(samp.engine._block_cache,
                              key=(m, False, plan, False, 0))
    best_full = best_samp = float("inf")
    reps = max(reps, 32 // m)
    for _ in range(reps):
        t0 = time.perf_counter()
        full.run_rounds(m, block_size=m)
        best_full = min(best_full, time.perf_counter() - t0)
        t0 = time.perf_counter()
        recs = samp.run_rounds(m, block_size=m, participation=plan)
        best_samp = min(best_samp, time.perf_counter() - t0)
    full_ms = best_full / m * 1e3
    samp_ms = best_samp / m * 1e3
    timed_rounds = reps * m
    mean_cohort = sum(r["cohort_size"] for r in recs) / len(recs)

    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "block_rounds": m,
        "strategy": plan.strategy,
        "cohort_size": plan.cohort_size,
        "dropout_rate": (plan.dropout_rate if plan.strategy == "dropout"
                         else None),
        "mean_cohort": round(mean_cohort, 2),
        "full_ms_per_round": round(full_ms, 2),
        "sampled_ms_per_round": round(samp_ms, 2),
        # < 1 when compute tracks the cohort (gather-compact strategies);
        # ~1 for the masked dropout path (compute stays at K by design)
        "cost_vs_full": round(samp_ms / full_ms, 2),
        "cohort_fraction": round(mean_cohort / k, 2),
        # participation must not change the dispatch structure: still one
        # donated dispatch per M-round block — MEASURED over the timed
        # reps (host syncs mirror dispatches: one readback per block)
        "dispatches_per_round": round(samp_calls["n"] / timed_rounds, 4),
        "host_syncs_per_round": round(1.0 / m, 4),
    }
    print(f"{name} K={k} M={m} {plan.strategy}: full={full_ms:.1f}ms "
          f"sampled={samp_ms:.1f}ms/round (cost x{row['cost_vs_full']} at "
          f"cohort {mean_cohort:.1f}/{k}, measured dispatches/round "
          f"{row['dispatches_per_round']})", flush=True)
    return row


def bench_async(name: str, k: int, modalities, reps: int, m: int,
                plan) -> dict:
    """Asynchronous buffered federation through the fused-block executor:
    nodes report after a sampled lag (and may crash, rejoin, or be
    poisoned), the server staleness-weights whatever landed this round —
    still ONE donated dispatch per M-round block (measured).  Reports
    rounds/sec, the staleness histogram of delivered reports, the
    per-node quarantine counters against an independent host-side count
    of poisoned report attempts, a finite-globals check, and the final
    cross-node CKA against a synchronous full-participation baseline
    (the convergence proxy CI guards for sign flips)."""
    import numpy as np
    import jax

    fedcfg = _light_fedcfg(k, modalities)
    sync = Federation(fedcfg, TINY)
    asyn = Federation(fedcfg, TINY)
    sync_recs = sync.run_rounds(m, block_size=m)       # warmup + compile
    all_recs = list(asyn.run_rounds(m, block_size=m, participation=plan))
    asy_calls = _count_calls(asyn.engine._block_cache,
                             key=(m, False, plan, False, 0))
    best_sync = best_async = float("inf")
    reps = max(reps, 32 // m)
    for _ in range(reps):
        t0 = time.perf_counter()
        sync_recs = sync.run_rounds(m, block_size=m)
        best_sync = min(best_sync, time.perf_counter() - t0)
        t0 = time.perf_counter()
        recs = asyn.run_rounds(m, block_size=m, participation=plan)
        best_async = min(best_async, time.perf_counter() - t0)
        all_recs += recs
    sync_ms = best_sync / m * 1e3
    async_ms = best_async / m * 1e3
    timed_rounds = reps * m
    # staleness histogram over DELIVERED reports (lag in rounds)
    hist = {}
    for r in all_recs:
        for lag, d in zip(r["staleness"], r["delivered"]):
            if d > 0:
                hist[int(lag)] = hist.get(int(lag), 0) + 1
    n_del = sum(hist.values())
    mean_stale = (sum(l * c for l, c in hist.items()) / n_del
                  if n_del else 0.0)
    # the device quarantine counters vs an INDEPENDENT host-side count:
    # a poisoned node must be quarantined on every round it starts a
    # report, so the two columns must agree exactly (CI checks this)
    quarantined = [int(round(x)) for x in all_recs[-1]["quarantined"]]
    expected_q = [0] * k
    for r in all_recs:
        for i in plan.poison_nodes:
            expected_q[i] += int(round(r["participation"][i]))
    finite = bool(np.isfinite(np.asarray(asyn.gbar)).all())
    for i in range(k):
        for leaf in jax.tree.leaves(asyn.node_params(i)):
            if leaf is not None:
                finite &= bool(np.isfinite(np.asarray(leaf)).all())

    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "block_rounds": m,
        "strategy": "async",
        "lag_dist": plan.lag_dist,
        "max_lag": plan.max_lag,
        "crash_rate": plan.crash_rate,
        "poison_nodes": list(plan.poison_nodes),
        "sync_ms_per_round": round(sync_ms, 2),
        "async_ms_per_round": round(async_ms, 2),
        "rounds_per_sec": round(1e3 / async_ms, 2),
        "cost_vs_sync": round(async_ms / sync_ms, 2),
        # async must not change the dispatch structure: still one donated
        # dispatch per M-round block — MEASURED over the timed reps
        "dispatches_per_round": round(asy_calls["n"] / timed_rounds, 4),
        "host_syncs_per_round": round(1.0 / m, 4),
        "staleness_hist": {str(l): hist[l] for l in sorted(hist)},
        "mean_staleness": round(mean_stale, 3),
        "n_delivered": n_del,
        "quarantined": quarantined,
        "expected_quarantined": expected_q,
        "finite_global": finite,
        "async_final_cka": round(float(all_recs[-1]["cross_node_cka"]), 4),
        "sync_final_cka": round(float(sync_recs[-1]["cross_node_cka"]), 4),
    }
    print(f"{name} K={k} M={m} {plan.lag_dist}: sync={sync_ms:.1f}ms "
          f"async={async_ms:.1f}ms/round ({row['rounds_per_sec']} r/s, "
          f"measured dispatches/round {row['dispatches_per_round']}) "
          f"stale-hist={row['staleness_hist']} "
          f"quarantined={quarantined} finite={finite}", flush=True)
    return row


def bench_gram_backend(name: str, k: int, modalities, rounds: int) -> dict:
    """Server-step Gram backend: reference jnp vs the Pallas kernel (MXU
    path on TPU; interpret mode here, so the CPU number is a correctness /
    dispatch-overhead datapoint, not a kernel speed claim)."""
    fedcfg = _fedcfg(k, modalities)
    ref_ms = _time_rounds(Federation(fedcfg, TINY,
                                     gram_backend="reference"), rounds)
    pal_ms = _time_rounds(Federation(fedcfg, TINY,
                                     gram_backend="pallas"), rounds)
    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "reference_ms_per_round": round(ref_ms, 2),
        "pallas_interpret_ms_per_round": round(pal_ms, 2),
        "backend_note": ("pallas runs in interpreter mode on CPU; "
                         "the MXU-tiled path targets TPU"),
    }
    print(f"{name} K={k}: gram reference={ref_ms:.1f}ms "
          f"pallas(interpret)={pal_ms:.1f}ms", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config CI smoke: K=2, 1 timed round, "
                         "separate output file")
    ap.add_argument("--only", default=None,
                    help="substring filter: run only the matching rows "
                         "and MERGE them into an existing output JSON "
                         "(other rows are kept as-is)")
    ap.add_argument("--out", default=None)
    args, _ = ap.parse_known_args()
    out = args.out or ("BENCH_federation.smoke.json" if args.smoke
                       else "BENCH_federation.json")
    from repro.core.participation import ParticipationPlan
    if args.smoke:
        ks, rounds = (2,), 1
        sweep_modalities = ("genetics", "tabular")
        mixed = ("genetics", "tabular")
        mixed_k = 2
        fused_ms = (2,)                    # CI smoke: M=2 fused block
        fused_modalities = ("genetics", "tabular")
        gram_k = 2
        # one modality -> one width bucket, so the C=1 cohort satisfies
        # the >= 1-slot-per-bucket allocation
        part_rows = [("sampled_cohort_c1_of_k2", 2, ("tabular",), 2,
                      ParticipationPlan(strategy="uniform", cohort_size=1))]
        async_rows = [
            ("async_lagged_k2", 2, fused_modalities, 2,
             ParticipationPlan(strategy="async", lag_dist="geometric",
                               lag_p=0.5, max_lag=2, crash_rate=0.1,
                               rejoin_rate=0.5, seed=11)),
            ("quarantine_1_poisoned", 2, fused_modalities, 2,
             ParticipationPlan(strategy="async", lag_dist="fixed", lag=0,
                               poison_nodes=(1,), seed=13)),
        ]
    else:
        ks = (4, 8) if args.quick else (4, 8, 16)
        rounds = 2 if args.quick else 3
        sweep_modalities = ("image", "text")
        mixed = MIXED_MODALITIES
        mixed_k = 8
        fused_ms = (4,) if args.quick else (4, 16)
        # narrow tokenizers keep per-round compute small: the high-round-
        # rate regime where the host round-trip (dispatch + blocking metric
        # readback) is a visible fraction of the round — what block fusion
        # amortises
        fused_modalities = ("genetics", "tabular")
        gram_k = 8
        # participation rows ride the M=4 fused block: per-round cost must
        # track the cohort size while dispatches stay at 1/M per round
        part_rows = [
            ("sampled_cohort_c4_of_k8", 8, fused_modalities, 4,
             ParticipationPlan(strategy="uniform", cohort_size=4)),
            ("dropout_p25", 8, fused_modalities, 4,
             ParticipationPlan(strategy="dropout", dropout_rate=0.25)),
        ]
        async_rows = [
            ("async_lagged_k8", 8, fused_modalities, 4,
             ParticipationPlan(strategy="async", lag_dist="geometric",
                               lag_p=0.5, max_lag=4, crash_rate=0.1,
                               rejoin_rate=0.5, seed=11)),
            ("quarantine_1_poisoned", 8, fused_modalities, 4,
             ParticipationPlan(strategy="async", lag_dist="fixed", lag=1,
                               poison_nodes=(1,), seed=13)),
        ]
    jobs = [(f"round_latency_k{k}",
             lambda k=k: bench_cfg(f"round_latency_k{k}", k,
                                   sweep_modalities, rounds))
            for k in ks]
    jobs.append((f"mixed_width_bucketed_k{mixed_k}",
                 lambda: bench_mixed_bucketed(
                     f"mixed_width_bucketed_k{mixed_k}", mixed_k, mixed,
                     rounds)))
    jobs += [(f"fused_rounds_m{m}",
              lambda m=m: bench_fused_rounds(f"fused_rounds_m{m}", mixed_k,
                                             fused_modalities, rounds, m))
             for m in fused_ms]
    jobs += [(name, lambda a=(name, k, mods, rounds, m, plan):
              bench_participation(*a))
             for name, k, mods, m, plan in part_rows]
    jobs += [(name, lambda a=(name, k, mods, rounds, m, plan):
              bench_async(*a))
             for name, k, mods, m, plan in async_rows]
    jobs.append((f"gram_backend_k{gram_k}",
                 lambda: bench_gram_backend(f"gram_backend_k{gram_k}",
                                            gram_k, sweep_modalities,
                                            rounds)))
    if args.only:
        jobs = [(n, fn) for n, fn in jobs if args.only in n]
        if not jobs:
            print(f"--only {args.only!r} matches no bench rows")
            return
    rows = [fn() for _, fn in jobs]
    results = {
        "bench": "federation_round_latency",
        "model": "fedmm-small (reduced: 2L/64d)",
        "backend": "cpu",
        "rows": rows,
    }
    if args.only and os.path.exists(out):
        # merge mode: replace matching rows in the existing JSON in place,
        # append rows it didn't have, keep everything else untouched
        with open(out) as fh:
            old = json.load(fh)
        fresh = {r["name"]: r for r in rows}
        merged = [fresh.pop(r.get("name"), r) for r in old.get("rows", ())]
        merged += list(fresh.values())
        results = dict(old)
        results["rows"] = merged
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
