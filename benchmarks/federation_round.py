"""Round-latency benchmark: sequential per-node loop vs node-stacked engine,
plus the width-bucketed vs pad-to-max-width engine layouts.

The sequential reference dispatches one jitted step per node per local step
(K x E per round) and tokenizes each batch eagerly on the host; the engine
runs the whole round — vmapped local epochs per width bucket + the server
step — as ONE compiled call with donated round-state buffers.  This bench
measures wall-clock per round for both at K in {4, 8, 16} and writes
``BENCH_federation.json``.

The K sweep uses the image+text modality pair; the ``mixed_width`` row runs
the full 4-modality mix (192..2048-dim tokenizers) and compares the legacy
single-bucket layout (every node padded to 2048, narrow nodes paying the
quadratic w^2 padding tax) against width bucketing, which groups nodes by
tokenizer width inside the same single-dispatch round.  A peak-memory
column (XLA ``memory_analysis`` on the compiled round) reports the
round-state donation savings: donated buffers alias outputs onto inputs,
so peak round-state memory stays ~1x instead of 2x.

Run: PYTHONPATH=src python -m benchmarks.federation_round [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.configs import get_config
from repro.core.federation import (Federation, FederationConfig,
                                   SequentialFederation)

TINY = get_config("fedmm-small").with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32")

LOCAL_STEPS = 4
MIXED_MODALITIES = ("image", "text", "genetics", "tabular")


def _fedcfg(k: int, modalities) -> FederationConfig:
    return FederationConfig(n_nodes=k, rounds=1, local_steps=LOCAL_STEPS,
                            local_batch=8, method="geolora", lora_rank=4,
                            anchors_per_class=2, n_tokens=4,
                            modalities=modalities)


def _time_rounds(f, rounds: int) -> float:
    """Best-of-N ms/round (min is the robust latency estimator under CPU
    contention; the first round is warmup and pays compilation)."""
    f.run_round()
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        f.run_round()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _peak_bytes(f: Federation) -> int:
    """Estimated peak live bytes of one compiled round: arguments + outputs
    + XLA temporaries, minus the donated input/output aliases."""
    args = (f._trains, f._opts, f._keys, f.gbar, f._staticss,
            (None,) * len(f._trains))
    ma = f.engine.round_fn.lower(*args).compile().memory_analysis()
    return int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)


def bench_cfg(name: str, k: int, modalities, rounds: int) -> dict:
    fedcfg = _fedcfg(k, modalities)
    seq_ms = _time_rounds(SequentialFederation(fedcfg, TINY), rounds)
    eng_ms = _time_rounds(Federation(fedcfg, TINY), rounds)
    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "sequential_ms_per_round": round(seq_ms, 2),
        "engine_ms_per_round": round(eng_ms, 2),
        "speedup": round(seq_ms / eng_ms, 2),
        # dispatch structure: the loop issues one jitted call per node per
        # local step; the engine compiles the whole round into one call
        "sequential_dispatches_per_round": k * LOCAL_STEPS,
        "engine_dispatches_per_round": 1,
    }
    print(f"{name} K={k}: sequential={seq_ms:.1f}ms "
          f"engine={eng_ms:.1f}ms speedup={row['speedup']}x", flush=True)
    return row


def bench_mixed_bucketed(name: str, k: int, modalities, rounds: int) -> dict:
    """Padded (single-bucket, pad-to-max-width) vs width-bucketed engine on
    a heterogeneous-width modality mix, plus the donation memory column."""
    fedcfg = _fedcfg(k, modalities)
    seq_ms = _time_rounds(SequentialFederation(fedcfg, TINY), rounds)

    padded = Federation(fedcfg, TINY, width_bucketing=False)
    padded_peak = _peak_bytes(padded)
    padded_ms = _time_rounds(padded, rounds)

    bucketed = Federation(fedcfg, TINY)
    bucketed_peak = _peak_bytes(bucketed)
    no_donate_peak = _peak_bytes(Federation(fedcfg, TINY, donate=False))
    bucketed_ms = _time_rounds(bucketed, rounds)

    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "bucket_widths": list(bucketed._bucket_widths),
        "sequential_ms_per_round": round(seq_ms, 2),
        "padded_engine_ms_per_round": round(padded_ms, 2),
        "engine_ms_per_round": round(bucketed_ms, 2),
        "speedup": round(seq_ms / bucketed_ms, 2),
        "padded_speedup": round(seq_ms / padded_ms, 2),
        "bucketed_vs_padded": round(padded_ms / bucketed_ms, 2),
        "sequential_dispatches_per_round": k * LOCAL_STEPS,
        "engine_dispatches_per_round": 1,
        # donation column: peak live bytes of the compiled round
        "peak_bytes_donated": bucketed_peak,
        "peak_bytes_no_donation": no_donate_peak,
        "donation_saved_bytes": no_donate_peak - bucketed_peak,
        "padded_peak_bytes_donated": padded_peak,
    }
    print(f"{name} K={k}: sequential={seq_ms:.1f}ms padded={padded_ms:.1f}ms "
          f"bucketed={bucketed_ms:.1f}ms "
          f"(bucketed vs padded {row['bucketed_vs_padded']}x, "
          f"vs sequential {row['speedup']}x) "
          f"peak {bucketed_peak/1e6:.1f}MB donated vs "
          f"{no_donate_peak/1e6:.1f}MB undonated", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config CI smoke: K=2, 1 timed round, "
                         "separate output file")
    ap.add_argument("--out", default=None)
    args, _ = ap.parse_known_args()
    out = args.out or ("BENCH_federation.smoke.json" if args.smoke
                       else "BENCH_federation.json")
    if args.smoke:
        ks, rounds = (2,), 1
        sweep_modalities = ("genetics", "tabular")
        mixed = ("genetics", "tabular")
        mixed_k = 2
    else:
        ks = (4, 8) if args.quick else (4, 8, 16)
        rounds = 2 if args.quick else 3
        sweep_modalities = ("image", "text")
        mixed = MIXED_MODALITIES
        mixed_k = 8
    rows = [bench_cfg(f"round_latency_k{k}", k, sweep_modalities, rounds)
            for k in ks]
    rows.append(bench_mixed_bucketed(
        f"mixed_width_bucketed_k{mixed_k}", mixed_k, mixed, rounds))
    results = {
        "bench": "federation_round_latency",
        "model": "fedmm-small (reduced: 2L/64d)",
        "backend": "cpu",
        "rows": rows,
    }
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
