"""Round-latency benchmark: sequential per-node loop vs node-stacked engine.

The sequential reference dispatches one jitted step per node per local step
(K x E per round) and tokenizes each batch eagerly on the host; the engine
runs the whole round — E vmapped local epochs + the server step — as ONE
compiled call.  This bench measures wall-clock per round for both at
K in {4, 8, 16} and writes ``BENCH_federation.json``.

The K sweep uses the width-matched image+text modality pair (1024/2048-dim
tokenizers), which isolates round-orchestration cost.  A separate
``mixed_width`` row runs the full 4-modality mix (192..2048-dim) where the
engine pays the padding-to-max-width tax for narrow-modality nodes — the
known cost of serving heterogeneous widths from one compiled program.

Run: PYTHONPATH=src python -m benchmarks.federation_round [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.configs import get_config
from repro.core.federation import (Federation, FederationConfig,
                                   SequentialFederation)

TINY = get_config("fedmm-small").with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32")

LOCAL_STEPS = 4


def _fedcfg(k: int, modalities) -> FederationConfig:
    return FederationConfig(n_nodes=k, rounds=1, local_steps=LOCAL_STEPS,
                            local_batch=8, method="geolora", lora_rank=4,
                            anchors_per_class=2, n_tokens=4,
                            modalities=modalities)


def _time_rounds(f, rounds: int) -> float:
    """Best-of-N ms/round (min is the robust latency estimator under CPU
    contention; the first round is warmup and pays compilation)."""
    f.run_round()
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        f.run_round()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def bench_cfg(name: str, k: int, modalities, rounds: int) -> dict:
    fedcfg = _fedcfg(k, modalities)
    seq_ms = _time_rounds(SequentialFederation(fedcfg, TINY), rounds)
    eng_ms = _time_rounds(Federation(fedcfg, TINY), rounds)
    row = {
        "name": name,
        "k_nodes": k,
        "modalities": list(modalities),
        "local_steps": LOCAL_STEPS,
        "sequential_ms_per_round": round(seq_ms, 2),
        "engine_ms_per_round": round(eng_ms, 2),
        "speedup": round(seq_ms / eng_ms, 2),
        # dispatch structure: the loop issues one jitted call per node per
        # local step; the engine compiles the whole round into one call
        "sequential_dispatches_per_round": k * LOCAL_STEPS,
        "engine_dispatches_per_round": 1,
    }
    print(f"{name} K={k}: sequential={seq_ms:.1f}ms "
          f"engine={eng_ms:.1f}ms speedup={row['speedup']}x", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_federation.json")
    args, _ = ap.parse_known_args()
    ks = (4, 8) if args.quick else (4, 8, 16)
    rounds = 2 if args.quick else 3
    rows = [bench_cfg(f"round_latency_k{k}", k, ("image", "text"), rounds)
            for k in ks]
    rows.append(bench_cfg(
        "mixed_width_padding_tax_k8", 8,
        ("image", "text", "genetics", "tabular"), rounds))
    results = {
        "bench": "federation_round_latency",
        "model": "fedmm-small (reduced: 2L/64d)",
        "backend": "cpu",
        "rows": rows,
    }
    with open(args.out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
