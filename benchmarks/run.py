"""Benchmark harness — one function per paper claim/table.

The paper is a methods paper: its two tables are literature comparisons,
and its quantitative claims are (a) >99.9% communication reduction from
GeoLoRA at foundation-model scale, (b) O(B^2) Gram upload vs raw-activation
sharing, (c) CKA-regularised alignment of disjoint modalities, (d)
precision weighting suppressing bad nodes, (e) fixed-A update consistency.
Each bench validates one claim and prints ``name,us_per_call,derived`` CSV.

Run: PYTHONPATH=src python -m benchmarks.run  [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

ROWS = []


def _timeit(fn, n=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6          # us


def _row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


# ----------------------------------------------------------------------
def bench_comm_reduction():
    """Claim: LoRA shrinks the per-round update by >99.9% at foundation
    scale (paper: 'gigabytes to megabytes')."""
    from repro.configs import get_config
    from repro.core import lora as L

    for arch in ("fedmm-base", "mistral-nemo-12b", "qwen3-32b"):
        cfg = get_config(arch)
        # analytic bytes: full model vs rank-16 B factors on attn targets
        full = cfg.param_count * 2                        # bf16
        d, dh = cfg.d_model, cfg.head_dim
        h, kv = max(cfg.n_heads, 1), max(cfg.n_kv_heads, 1)
        rank = 16
        per_layer_b = rank * (h * dh + 2 * kv * dh + d)   # wq wk wv wo B's
        lora = cfg.n_layers * per_layer_b * 2 + 32 * 32 * 4
        saving = 100.0 * (1 - lora / full)
        _row(f"comm_reduction_{arch}", 0.0,
             f"{saving:.3f}%_saved;up={lora/1e6:.2f}MB;full={full/1e9:.2f}GB")


def bench_gram_vs_activations():
    """Claim: Gram upload is O(B^2), far below raw anchor activations
    (B x L x d) — and shares only relational geometry."""
    from repro.configs import get_config
    cfg = get_config("fedmm-base")
    b, l, d = 32, 128, cfg.d_model
    gram = b * b * 4
    acts = b * l * d * 2
    _row("gram_vs_raw_activations", 0.0,
         f"gram={gram/1e3:.1f}KB;raw={acts/1e6:.2f}MB;"
         f"ratio={acts/gram:.0f}x")


def bench_cka_alignment(quick: bool):
    """Claim: CKA-regularised rounds align disjoint unpaired modalities."""
    from repro.configs import get_config
    from repro.core.federation import Federation, FederationConfig
    tiny = get_config("fedmm-small").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")
    rounds = 2 if quick else 5
    fed = FederationConfig(n_nodes=4, rounds=rounds, local_steps=5,
                           local_batch=16, method="geolora", lambda_geo=1.0)
    t0 = time.perf_counter()
    f = Federation(fed, tiny)
    hist = f.run()
    us = (time.perf_counter() - t0) / rounds * 1e6
    _row("cka_alignment_geolora", us,
         f"xcka_r0={hist[0]['cross_node_cka']:.3f};"
         f"xcka_final={hist[-1]['cross_node_cka']:.3f};"
         f"task_final={hist[-1]['task_loss']:.3f}")

    # ablation: lambda_geo = 0 (no alignment regulariser)
    fed0 = FederationConfig(n_nodes=4, rounds=rounds, local_steps=5,
                            local_batch=16, method="geolora", lambda_geo=0.0)
    h0 = Federation(fed0, tiny).run()
    _row("cka_alignment_ablation_lambda0", 0.0,
         f"xcka_final={h0[-1]['cross_node_cka']:.3f}")


def bench_precision_weighting(quick: bool):
    """Claim: LAP precision weighting downweights a corrupted node."""
    from repro.configs import get_config
    from repro.core.federation import Federation, FederationConfig
    tiny = get_config("fedmm-small").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")
    fed = FederationConfig(n_nodes=4, rounds=2, local_steps=5,
                           local_batch=16, method="geolora",
                           aggregation="precision", corrupt_nodes=(2,))
    f = Federation(fed, tiny)
    hist = f.run()
    w = hist[-1]["weights"]
    others = sum(w[i] for i in range(4) if i != 2) / 3
    _row("precision_weighting_corrupt_node", 0.0,
         f"w_corrupt={w[2]:.3f};w_others_mean={others:.3f};"
         f"suppression={others/max(w[2],1e-6):.2f}x")


def bench_fixed_a_consistency():
    """Claim (Eq. 4): frozen shared A makes B-averaging exact."""
    import numpy as np
    rng = np.random.default_rng(0)
    a = rng.standard_normal((64, 8)).astype(np.float32)
    bs = rng.standard_normal((4, 8, 64)).astype(np.float32)
    exact = np.mean([a @ b for b in bs], axis=0)
    ours = a @ bs.mean(0)
    err = float(np.abs(exact - ours).max())
    a_k = rng.standard_normal((4, 64, 8)).astype(np.float32)
    naive = a_k.mean(0) @ bs.mean(0)
    hetero = np.mean([ak @ b for ak, b in zip(a_k, bs)], axis=0)
    err_het = float(np.abs(hetero - naive).max())
    _row("fixed_a_aggregation_consistency", 0.0,
         f"fixedA_err={err:.2e};heteroA_err={err_het:.3f}")


def bench_kernels(quick: bool):
    """Kernel wall-times (jnp oracle path on CPU; the Pallas kernels target
    TPU and are correctness-validated in interpret mode by the tests)."""
    from repro.kernels import ref
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (128, 1024))
    g = jax.jit(ref.cosine_gram_ref)
    _row("gram_128x1024_ref", _timeit(lambda: g(x).block_until_ready()),
         "oracle")
    w = jax.random.normal(k, (1024, 1024))
    a = jax.random.normal(k, (1024, 16))
    b = jax.random.normal(k, (16, 1024))
    lm = jax.jit(ref.lora_matmul_ref)
    _row("lora_matmul_1024_ref",
         _timeit(lambda: lm(x, w, a, b).block_until_ready()), "oracle")
    q = jax.random.normal(k, (8, 512, 64))
    fa = jax.jit(lambda q: ref.flash_attention_ref(q, q, q))
    _row("attention_512_ref",
         _timeit(lambda: fa(q).block_until_ready()), "oracle")
    da = jax.random.uniform(k, (4, 512, 256), minval=0.5, maxval=0.99)
    db = jax.random.normal(k, (4, 512, 256))
    h0 = jnp.zeros((4, 256))
    ss = jax.jit(ref.selective_scan_ref)
    _row("selective_scan_512_ref",
         _timeit(lambda: ss(da, db, h0)[0].block_until_ready()), "oracle")


def bench_geodora_magnitude_direction(quick: bool):
    """Claim (Eq. 5): GeoDoRA decouples magnitude from direction — scaling
    a node's inputs moves its magnitudes, not its aligned direction."""
    from repro.core import lora as L
    from repro.models.common import dora_column_norm, linear, make_linear
    import numpy as np
    key = jax.random.PRNGKey(1)
    lin = make_linear(key, 32, 24, jnp.float32)
    from repro.models.common import add_dora, add_lora
    d = add_dora(add_lora(key, lin, 4, jnp.float32))
    d["lora_B"] = 0.1 * jax.random.normal(key, (4, 24))
    x = jax.random.normal(key, (16, 32))
    y1 = linear(x, d)
    d2 = dict(d, dora_m=2.0 * d["dora_m"])
    y2 = linear(x, d2)
    ratio = float(jnp.median(jnp.abs(y2 / y1)))
    _row("geodora_magnitude_scaling", 0.0,
         f"output_scale_ratio={ratio:.3f}(expect~2)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fed-round", action="store_true",
                    help="also run the sequential-vs-engine round-latency "
                         "bench (writes BENCH_federation.json)")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    bench_comm_reduction()
    bench_gram_vs_activations()
    bench_fixed_a_consistency()
    bench_geodora_magnitude_direction(args.quick)
    bench_kernels(args.quick)
    bench_precision_weighting(args.quick)
    bench_cka_alignment(args.quick)
    if args.fed_round:
        from benchmarks.federation_round import main as fed_round_main
        fed_round_main()


if __name__ == "__main__":
    main()
