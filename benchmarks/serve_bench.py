"""Continuous-batching serving benchmark: fused-block engine vs the
legacy per-token loop, across model families.

For each family the same request stream runs through

  - ``ServeEngine``: slot-stacked cache pool, M decode steps fused into
    one jitted ``lax.scan`` with on-device sampling/stop accounting, one
    host readback per block, mid-decode admission; and
  - ``naive_generate``: the legacy loop — one jit dispatch plus one
    blocking argmax readback per token, head-of-line batches.

Reported per row (everything MEASURED, nothing asserted):

  - tokens/s end-to-end for both paths and the speedup;
  - dispatches/token and host-syncs/token from the engine's counters
    (CI guards these at <= 1/M via ``check_smoke``);
  - TTFT p50/p99 under Poisson arrivals at swept rates (engine runs
    with ``sync_ttft`` — a per-REQUEST sync used only for timestamping);
  - the ``decode_roofline`` memory-bound prediction (bytes/token over
    HBM bandwidth) next to measured throughput, so the gap between
    bandwidth-bound ideal and dispatch-bound reality is visible.

Run:  python -m benchmarks.serve_bench            -> BENCH_serve.json
      python -m benchmarks.serve_bench --smoke    -> BENCH_serve.smoke.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import time

import jax

from repro.configs import get_config, reduced
from repro.models import transformer as T
from repro.roofline.analysis import decode_roofline
from repro.serve import (ServeConfig, ServeEngine, naive_generate,
                         poisson_requests)


def _prep(cfg):
    """Expert-capacity headroom: token dropping depends on batch
    composition, which would make the batched engine and the batch-1
    oracle legitimately diverge — not what this bench measures."""
    if cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=8.0))
    return cfg


def _tiny(arch):
    """Federation-smoke-sized config (2L/64d) for the CI lane."""
    cfg = reduced(get_config(arch))
    kw = dict(n_layers=2, d_model=64, d_ff=128 if cfg.d_ff else 0,
              vocab_size=256)
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads or 1, 2),
                  head_dim=16)
    if cfg.family == "ssm":
        kw.update(ssm=dataclasses.replace(cfg.ssm, chunk=16))
    if cfg.family == "hybrid":
        kw.update(n_layers=3, n_kv_heads=1,
                  rglru=dataclasses.replace(cfg.rglru, lru_width=64,
                                            local_window=32, chunk=16))
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    return _prep(cfg.with_(**kw))


def _gen_tokens(records):
    return sum(len(r.tokens) for r in records.values())


def bench_family(name, cfg, *, n_slots, block_steps, cache_len, n_requests,
                 prompt_len, max_new, max_new_mix=(), ttft_rates=(),
                 reps=1, seed=0):
    """One engine-vs-naive row.  ``max_new_mix`` cycles per-request
    generation lengths — the heavy-tailed regime where the naive loop's
    head-of-line blocking wastes batch slots and continuous admission
    back-fills them.  Timing is best-of-``reps`` after a full warm-up
    pass of each path (CPU wall-clock is noisy)."""
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    scfg = ServeConfig(n_slots=n_slots, cache_len=cache_len,
                       block_steps=block_steps, max_new_tokens=max_new)
    reqs = poisson_requests(n_requests, 0.0, prompt_len=prompt_len,
                            vocab_size=cfg.vocab_size, seed=seed,
                            max_new=None)
    if max_new_mix:
        reqs = [dataclasses.replace(r, max_new=max_new_mix[i %
                                                          len(max_new_mix)])
                for i, r in enumerate(reqs)]

    # ---- engine throughput (warm-up run compiles admission + block) --
    eng = ServeEngine(params, cfg, scfg)
    eng.serve(reqs[:n_slots])
    eng_s = float("inf")
    for _ in range(reps):
        for k in eng.stats:
            eng.stats[k] = 0
        t0 = time.perf_counter()
        recs = eng.serve(reqs)
        eng_s = min(eng_s, time.perf_counter() - t0)
    eng_tokens = _gen_tokens(recs)
    st = eng.stats

    # ---- naive baseline (same batch width, head-of-line) -------------
    # full-stream warm-up: a ragged tail group has its own batch shape,
    # and paying its compile inside the timed run would flatter the engine
    naive_generate(params, cfg, reqs, scfg)
    naive_s = float("inf")
    for _ in range(reps):
        nstats = {}
        t0 = time.perf_counter()
        nrecs = naive_generate(params, cfg, reqs, scfg, stats=nstats)
        naive_s = min(naive_s, time.perf_counter() - t0)
    naive_tokens = _gen_tokens(nrecs)

    mismatch = sum(recs[r.rid].tokens != nrecs[r.rid].tokens for r in reqs)
    roof = decode_roofline(cfg, n_slots=n_slots, cache_len=cache_len)
    eng_tps = eng_tokens / eng_s
    row = {
        "name": name,
        "family": cfg.family,
        "n_slots": n_slots,
        "block_steps": block_steps,
        "cache_len": cache_len,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "max_new_mix": list(max_new_mix),
        "engine_tokens_per_s": round(eng_tps, 2),
        "naive_tokens_per_s": round(naive_tokens / naive_s, 2),
        "speedup": round((eng_tokens / eng_s) / (naive_tokens / naive_s), 2),
        "tokens_mismatched_vs_naive": mismatch,
        # dispatch structure, measured from the engine's counters
        "dispatches_per_token": round(
            st["block_dispatches"] / max(st["block_tokens"], 1), 4),
        "host_syncs_per_token": round(
            st["block_syncs"] / max(st["block_tokens"], 1), 4),
        "per_token_extra_syncs": st["request_reads"],
        "naive_dispatches_per_token": round(
            nstats["decode_dispatches"] / max(nstats["decode_tokens"], 1), 4),
        "naive_host_syncs_per_token": round(
            nstats["host_syncs"] / max(nstats["decode_tokens"], 1), 4),
        # memory-bound prediction vs measurement
        "roofline": roof,
        "pred_tokens_per_s": round(roof["pred_tokens_per_s"], 2),
        "measured_over_pred": round(eng_tps / roof["pred_tokens_per_s"], 6),
    }

    # ---- TTFT under Poisson arrivals (per-request sync_ttft runs) ----
    ttft = {}
    for rate in ttft_rates:
        sreqs = poisson_requests(n_requests, rate, prompt_len=prompt_len,
                                 vocab_size=cfg.vocab_size, seed=seed + 1)
        e2 = ServeEngine(params, cfg, scfg)
        rr = e2.serve(sreqs, sync_ttft=True)
        lats = sorted(1e3 * r.ttft_s for r in rr.values()
                      if r.ttft_s is not None)
        ttft[f"rate_{rate:g}"] = {
            "p50_ms": round(statistics.median(lats), 2),
            "p99_ms": round(lats[min(len(lats) - 1,
                                     int(0.99 * len(lats)))], 2),
        }
    if ttft:
        row["ttft"] = ttft
    print(f"{name}: engine {row['engine_tokens_per_s']} tok/s, naive "
          f"{row['naive_tokens_per_s']} tok/s ({row['speedup']}x), "
          f"disp/tok {row['dispatches_per_token']} "
          f"(naive {row['naive_dispatches_per_token']})", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config CI smoke, separate output file")
    ap.add_argument("--out", default=None)
    args, _ = ap.parse_known_args()
    out = args.out or ("BENCH_serve.smoke.json" if args.smoke
                       else "BENCH_serve.json")
    if args.smoke:
        fams = [("dense_gqa", _tiny("qwen3-32b")),
                ("ssm_mamba", _tiny("falcon-mamba-7b"))]
        rows = [bench_family(name, cfg, n_slots=4, block_steps=4,
                             cache_len=48, n_requests=6, prompt_len=8,
                             max_new=8) for name, cfg in fams]
    else:
        # primary regime: small per-step compute (dispatch-bound, the
        # CPU proxy for accelerator decode) + heavy-tailed generation
        # lengths, where head-of-line blocking wastes the naive loop's
        # batch slots and continuous admission back-fills them
        mix = (96, 4, 64, 8, 96, 4, 32, 8)
        fams = [("dense_gqa", _tiny("qwen3-32b")),
                ("swa_ring", _tiny("mistral-nemo-12b")),
                ("mla_latent", _tiny("deepseek-v2-236b")),
                ("ssm_mamba", _tiny("falcon-mamba-7b")),
                ("hybrid_rglru", _tiny("recurrentgemma-9b"))]
        kw = dict(n_slots=8, block_steps=16, cache_len=128, n_requests=24,
                  prompt_len=8, max_new=96, max_new_mix=mix, reps=3,
                  ttft_rates=(8.0, 32.0))
        rows = [bench_family(name, cfg, **kw) for name, cfg in fams]
        # secondary regime: wider (d=256) models where per-step compute
        # dominates dispatch overhead on CPU — the fused-block win
        # shrinks, which the roofline column makes legible
        for name, arch in (("dense_gqa_d256", "qwen3-32b"),
                           ("ssm_mamba_d256", "falcon-mamba-7b")):
            rows.append(bench_family(
                name, _prep(reduced(get_config(arch))), n_slots=8,
                block_steps=8, cache_len=128, n_requests=16, prompt_len=16,
                max_new=32, reps=2))
    results = {
        "bench": "serve_continuous_batching",
        "backend": jax.default_backend(),
        "rows": rows,
    }
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
