"""Continuous-batching serving benchmark: fused-block engine vs the
legacy per-token loop, across model families.

For each family the same request stream runs through

  - ``ServeEngine``: slot-stacked cache pool, M decode steps fused into
    one jitted ``lax.scan`` with on-device sampling/stop accounting, one
    host readback per block, mid-decode admission; and
  - ``naive_generate``: the legacy loop — one jit dispatch plus one
    blocking argmax readback per token, head-of-line batches.

Reported per row (everything MEASURED, nothing asserted):

  - tokens/s end-to-end for both paths and the speedup;
  - dispatches/token and host-syncs/token from the engine's counters
    (CI guards these at <= 1/M via ``check_smoke``);
  - TTFT p50/p99 under Poisson arrivals at swept rates (engine runs
    with ``sync_ttft`` — a per-REQUEST sync used only for timestamping);
  - the ``decode_roofline`` memory-bound prediction (bytes/token over
    HBM bandwidth) next to measured throughput, so the gap between
    bandwidth-bound ideal and dispatch-bound reality is visible.

Resilience rows (PR 8):

  - ``overload_*``: the same stream at 2x and 4x the MEASURED
    sustainable Poisson rate, with and without deadline-based shedding —
    goodput, terminal-state accounting, and TTFT p99 (shedding must hold
    p99 bounded where the no-shedding queue grows without bound);
  - ``chaos_*``: a deterministic seeded fault schedule (NaN-poisoned
    logits, a silent slot freeze, host delays, one simulated mid-stream
    crash recovered via snapshot/resume) — fault/stall/retry counters,
    exactly-one-terminal-state accounting, and the no-garbage invariant
    (every emitted token stream is a PREFIX of the fault-free run's).

Run:  python -m benchmarks.serve_bench            -> BENCH_serve.json
      python -m benchmarks.serve_bench --smoke    -> BENCH_serve.smoke.json
      python -m benchmarks.serve_bench --only chaos   (re-run matching
      rows and MERGE them into the existing JSON, like
      federation_round.py)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics
import tempfile
import time

import jax

from repro.configs import get_config, reduced
from repro.models import transformer as T
from repro.roofline.analysis import decode_roofline
from repro.serve import (FaultPlan, ServeConfig, ServeEngine,
                         SimulatedCrash, naive_generate, poisson_requests,
                         state_counts)


def _prep(cfg):
    """Expert-capacity headroom: token dropping depends on batch
    composition, which would make the batched engine and the batch-1
    oracle legitimately diverge — not what this bench measures."""
    if cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=8.0))
    return cfg


def _tiny(arch):
    """Federation-smoke-sized config (2L/64d) for the CI lane."""
    cfg = reduced(get_config(arch))
    kw = dict(n_layers=2, d_model=64, d_ff=128 if cfg.d_ff else 0,
              vocab_size=256)
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads or 1, 2),
                  head_dim=16)
    if cfg.family == "ssm":
        kw.update(ssm=dataclasses.replace(cfg.ssm, chunk=16))
    if cfg.family == "hybrid":
        kw.update(n_layers=3, n_kv_heads=1,
                  rglru=dataclasses.replace(cfg.rglru, lru_width=64,
                                            local_window=32, chunk=16))
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    return _prep(cfg.with_(**kw))


def _gen_tokens(records):
    return sum(len(r.tokens) for r in records.values())


def bench_family(name, cfg, *, n_slots, block_steps, cache_len, n_requests,
                 prompt_len, max_new, max_new_mix=(), ttft_rates=(),
                 reps=1, seed=0):
    """One engine-vs-naive row.  ``max_new_mix`` cycles per-request
    generation lengths — the heavy-tailed regime where the naive loop's
    head-of-line blocking wastes batch slots and continuous admission
    back-fills them.  Timing is best-of-``reps`` after a full warm-up
    pass of each path (CPU wall-clock is noisy)."""
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    scfg = ServeConfig(n_slots=n_slots, cache_len=cache_len,
                       block_steps=block_steps, max_new_tokens=max_new)
    reqs = poisson_requests(n_requests, 0.0, prompt_len=prompt_len,
                            vocab_size=cfg.vocab_size, seed=seed,
                            max_new=None)
    if max_new_mix:
        reqs = [dataclasses.replace(r, max_new=max_new_mix[i %
                                                          len(max_new_mix)])
                for i, r in enumerate(reqs)]

    # ---- engine throughput (warm-up run compiles admission + block) --
    eng = ServeEngine(params, cfg, scfg)
    eng.serve(reqs[:n_slots])
    eng_s = float("inf")
    for _ in range(reps):
        for k in eng.stats:
            eng.stats[k] = 0
        t0 = time.perf_counter()
        recs = eng.serve(reqs)
        eng_s = min(eng_s, time.perf_counter() - t0)
    eng_tokens = _gen_tokens(recs)
    st = eng.stats

    # ---- naive baseline (same batch width, head-of-line) -------------
    # full-stream warm-up: a ragged tail group has its own batch shape,
    # and paying its compile inside the timed run would flatter the engine
    naive_generate(params, cfg, reqs, scfg)
    naive_s = float("inf")
    for _ in range(reps):
        nstats = {}
        t0 = time.perf_counter()
        nrecs = naive_generate(params, cfg, reqs, scfg, stats=nstats)
        naive_s = min(naive_s, time.perf_counter() - t0)
    naive_tokens = _gen_tokens(nrecs)

    mismatch = sum(recs[r.rid].tokens != nrecs[r.rid].tokens for r in reqs)
    roof = decode_roofline(cfg, n_slots=n_slots, cache_len=cache_len)
    eng_tps = eng_tokens / eng_s
    row = {
        "name": name,
        "family": cfg.family,
        "n_slots": n_slots,
        "block_steps": block_steps,
        "cache_len": cache_len,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "max_new_mix": list(max_new_mix),
        "engine_tokens_per_s": round(eng_tps, 2),
        "naive_tokens_per_s": round(naive_tokens / naive_s, 2),
        "speedup": round((eng_tokens / eng_s) / (naive_tokens / naive_s), 2),
        "tokens_mismatched_vs_naive": mismatch,
        # dispatch structure, measured from the engine's counters
        "dispatches_per_token": round(
            st["block_dispatches"] / max(st["block_tokens"], 1), 4),
        "host_syncs_per_token": round(
            st["block_syncs"] / max(st["block_tokens"], 1), 4),
        "per_token_extra_syncs": st["request_reads"],
        "naive_dispatches_per_token": round(
            nstats["decode_dispatches"] / max(nstats["decode_tokens"], 1), 4),
        "naive_host_syncs_per_token": round(
            nstats["host_syncs"] / max(nstats["decode_tokens"], 1), 4),
        # memory-bound prediction vs measurement
        "roofline": roof,
        "pred_tokens_per_s": round(roof["pred_tokens_per_s"], 2),
        "measured_over_pred": round(eng_tps / roof["pred_tokens_per_s"], 6),
    }

    # ---- TTFT under Poisson arrivals (per-request sync_ttft runs) ----
    ttft = {}
    for rate in ttft_rates:
        sreqs = poisson_requests(n_requests, rate, prompt_len=prompt_len,
                                 vocab_size=cfg.vocab_size, seed=seed + 1)
        e2 = ServeEngine(params, cfg, scfg)
        rr = e2.serve(sreqs, sync_ttft=True)
        lats = sorted(1e3 * r.ttft_s for r in rr.values()
                      if r.ttft_s is not None)
        ttft[f"rate_{rate:g}"] = {
            "p50_ms": round(statistics.median(lats), 2),
            "p99_ms": round(lats[min(len(lats) - 1,
                                     int(0.99 * len(lats)))], 2),
        }
    if ttft:
        row["ttft"] = ttft
    print(f"{name}: engine {row['engine_tokens_per_s']} tok/s, naive "
          f"{row['naive_tokens_per_s']} tok/s ({row['speedup']}x), "
          f"disp/tok {row['dispatches_per_token']} "
          f"(naive {row['naive_dispatches_per_token']})", flush=True)
    return row


def _ttft_ms(records):
    """(p50_ms, p99_ms) over requests that received a first token."""
    lats = sorted(1e3 * r.ttft_s for r in records.values()
                  if r.ttft_s is not None)
    if not lats:
        return None, None
    return (round(statistics.median(lats), 2),
            round(lats[min(len(lats) - 1, int(0.99 * len(lats)))], 2))


def _accounting(records, n_requests):
    counts = state_counts(records)
    ok = sum(counts.get(s, 0) for s in
             ("completed", "shed", "timed_out", "failed")) == n_requests
    return counts, ok


def bench_overload(name, cfg, *, n_slots, block_steps, cache_len,
                   n_requests, prompt_len, max_new,
                   overload_xs=(2.0, 4.0), seed=0):
    """Graceful-degradation row: measure the sustainable service rate,
    then offer the stream at ``overload_xs`` times it, with and without
    SLO shedding.  Without shedding every request eventually runs and
    queue latency (TTFT p99) grows with the backlog; with a TTFT
    deadline + bounded queue, late requests are shed and the p99 of what
    IS served stays bounded near the deadline."""
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    base = ServeConfig(n_slots=n_slots, cache_len=cache_len,
                       block_steps=block_steps, max_new_tokens=max_new)
    calib = poisson_requests(n_requests, 0.0, prompt_len=prompt_len,
                             vocab_size=cfg.vocab_size, seed=seed)
    eng = ServeEngine(params, cfg, base)
    eng.serve(calib)                       # compile
    t0 = time.perf_counter()
    eng.serve(calib)
    svc_s = time.perf_counter() - t0       # all-at-once drain time
    sustainable = n_requests / svc_s
    ttft_deadline = 0.35 * svc_s
    row = {"name": name, "kind": "overload", "family": cfg.family,
           "n_slots": n_slots, "block_steps": block_steps,
           "n_requests": n_requests, "max_new": max_new,
           "sustainable_req_s": round(sustainable, 2),
           "ttft_deadline_s": round(ttft_deadline, 4), "sweeps": {}}
    last_stats = None
    for x in overload_xs:
        rate = x * sustainable
        reqs = poisson_requests(n_requests, rate, prompt_len=prompt_len,
                                vocab_size=cfg.vocab_size, seed=seed + 1)
        sweep = {"rate_req_s": round(rate, 2)}
        for label, scfg in (
                ("noshed", base),
                ("shed", dataclasses.replace(
                    base, ttft_deadline_s=ttft_deadline,
                    queue_cap=2 * n_slots))):
            e = ServeEngine(params, cfg, scfg)
            e.serve(calib[:n_slots])     # compile admit + block outside
            for k in e.stats:            # the timed window
                e.stats[k] = 0
            t0 = time.perf_counter()
            recs = e.serve(reqs, sync_ttft=True)
            wall = time.perf_counter() - t0
            counts, ok = _accounting(recs, n_requests)
            p50, p99 = _ttft_ms(recs)
            sweep[label] = {
                "counts": counts, "accounting_ok": ok,
                "goodput_req_s": round(counts["completed"] / wall, 2),
                "ttft_p50_ms": p50, "ttft_p99_ms": p99,
            }
            last_stats = e.stats
        sweep["shed_bounds_ttft_p99"] = (
            sweep["shed"]["ttft_p99_ms"] is not None
            and sweep["shed"]["ttft_p99_ms"]
            <= sweep["noshed"]["ttft_p99_ms"])
        row["sweeps"][f"x{x:g}"] = sweep
    st = last_stats
    row["dispatches_per_token"] = round(
        st["block_dispatches"] / max(st["block_tokens"], 1), 4)
    row["host_syncs_per_token"] = round(
        st["block_syncs"] / max(st["block_tokens"], 1), 4)
    top = row["sweeps"][f"x{overload_xs[-1]:g}"]
    print(f"{name}: sustainable {row['sustainable_req_s']} req/s; at "
          f"{overload_xs[-1]:g}x noshed p99 {top['noshed']['ttft_p99_ms']}"
          f"ms vs shed p99 {top['shed']['ttft_p99_ms']}ms "
          f"(shed {top['shed']['counts']['shed']}/{n_requests})",
          flush=True)
    return row


def bench_chaos(name, cfg, *, n_slots, block_steps, cache_len, n_requests,
                prompt_len, max_new, crash_after_block=2, seed=0):
    """Chaos row: a seeded deterministic fault schedule — NaN-poisoned
    logits on chosen global steps, a silent slot freeze the stall
    watchdog must catch, host-side block delays, and one simulated
    engine crash recovered through the serve snapshot.  Gated
    invariants: every request lands in exactly one terminal state, every
    emitted token stream is a PREFIX of the fault-free run's (no token
    derived from poisoned logits ever escapes), completed requests match
    the clean run exactly, and the dispatch structure stays <= 1/M."""
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    scfg = ServeConfig(n_slots=n_slots, cache_len=cache_len,
                       block_steps=block_steps, max_new_tokens=max_new,
                       max_attempts=3, retry_backoff_s=0.0,
                       stall_blocks=2, guard_nonfinite=True)
    reqs = poisson_requests(n_requests, 0.0, prompt_len=prompt_len,
                            vocab_size=cfg.vocab_size, seed=seed)
    clean = ServeEngine(params, cfg, scfg).serve(reqs)
    m = block_steps
    plan = FaultPlan(
        nan_steps=(m + 1, 3 * m), nan_slots=(0, min(2, n_slots - 1)),
        freeze_steps=tuple(range(2 * m, 5 * m)),
        freeze_slots=(min(1, n_slots - 1),),
        delay_blocks=(1, 3), delay_s=0.002,
        crash_after_block=crash_after_block)
    snap = os.path.join(tempfile.gettempdir(), f"serve_snap_{name}.npz")
    eng = ServeEngine(params, cfg, scfg)
    t0 = time.perf_counter()
    resumed = False
    try:
        recs = eng.serve(reqs, fault_plan=plan, snapshot_path=snap,
                         snapshot_every_blocks=1)
        stats = dict(eng.stats)
    except SimulatedCrash:
        eng2 = ServeEngine.resume(snap, params, cfg)
        recs = eng2.resume_serve(
            fault_plan=dataclasses.replace(plan, crash_after_block=-1))
        resumed = True
        stats = {k: eng.stats[k] + eng2.stats[k] for k in eng.stats}
    wall = time.perf_counter() - t0
    counts, ok = _accounting(recs, n_requests)
    prefix_ok = all(
        recs[r.rid].tokens == clean[r.rid].tokens[:len(recs[r.rid].tokens)]
        for r in reqs)
    completed_match = all(recs[r.rid].tokens == clean[r.rid].tokens
                          for r in reqs
                          if recs[r.rid].state == "completed")
    row = {
        "name": name, "kind": "chaos", "family": cfg.family,
        "n_slots": n_slots, "block_steps": block_steps,
        "n_requests": n_requests, "max_new": max_new,
        "counts": counts, "accounting_ok": ok,
        "goodput_req_s": round(counts["completed"] / wall, 2),
        "faults_detected": stats["faults_detected"],
        "stalls_detected": stats["stalls_detected"],
        "retries": sum(recs[r.rid].retries for r in reqs),
        "snapshot_writes": stats["snapshot_writes"],
        "resumed_after_crash": resumed,
        "prefix_clean_ok": prefix_ok,
        "completed_match_clean": completed_match,
        "dispatches_per_token": round(
            stats["block_dispatches"] / max(stats["block_tokens"], 1), 4),
        "host_syncs_per_token": round(
            stats["block_syncs"] / max(stats["block_tokens"], 1), 4),
    }
    if os.path.exists(snap):
        os.remove(snap)
    print(f"{name}: {counts} | faults {row['faults_detected']} stalls "
          f"{row['stalls_detected']} retries {row['retries']} | resumed "
          f"{resumed} | prefix_clean {prefix_ok}", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config CI smoke, separate output file")
    ap.add_argument("--only", default=None,
                    help="substring filter: run only the matching rows "
                         "and MERGE them into an existing output JSON "
                         "(other rows are kept as-is)")
    ap.add_argument("--out", default=None)
    args, _ = ap.parse_known_args()
    out = args.out or ("BENCH_serve.smoke.json" if args.smoke
                       else "BENCH_serve.json")
    if args.smoke:
        fams = [("dense_gqa", _tiny("qwen3-32b")),
                ("ssm_mamba", _tiny("falcon-mamba-7b"))]
        jobs = [(name, lambda name=name, cfg=cfg: bench_family(
                    name, cfg, n_slots=4, block_steps=4, cache_len=48,
                    n_requests=6, prompt_len=8, max_new=8))
                for name, cfg in fams]
        jobs.append(("chaos_dense_gqa", lambda: bench_chaos(
            "chaos_dense_gqa", _tiny("qwen3-32b"), n_slots=4,
            block_steps=4, cache_len=48, n_requests=8, prompt_len=8,
            max_new=12)))
        jobs.append(("overload_dense_gqa", lambda: bench_overload(
            "overload_dense_gqa", _tiny("qwen3-32b"), n_slots=4,
            block_steps=4, cache_len=48, n_requests=32, prompt_len=8,
            max_new=16, overload_xs=(4.0,))))
    else:
        # primary regime: small per-step compute (dispatch-bound, the
        # CPU proxy for accelerator decode) + heavy-tailed generation
        # lengths, where head-of-line blocking wastes the naive loop's
        # batch slots and continuous admission back-fills them
        mix = (96, 4, 64, 8, 96, 4, 32, 8)
        fams = [("dense_gqa", _tiny("qwen3-32b")),
                ("swa_ring", _tiny("mistral-nemo-12b")),
                ("mla_latent", _tiny("deepseek-v2-236b")),
                ("ssm_mamba", _tiny("falcon-mamba-7b")),
                ("hybrid_rglru", _tiny("recurrentgemma-9b"))]
        kw = dict(n_slots=8, block_steps=16, cache_len=128, n_requests=24,
                  prompt_len=8, max_new=96, max_new_mix=mix, reps=3,
                  ttft_rates=(8.0, 32.0))
        jobs = [(name, lambda name=name, cfg=cfg: bench_family(
                    name, cfg, **kw)) for name, cfg in fams]
        # secondary regime: wider (d=256) models where per-step compute
        # dominates dispatch overhead on CPU — the fused-block win
        # shrinks, which the roofline column makes legible
        for name, arch in (("dense_gqa_d256", "qwen3-32b"),
                           ("ssm_mamba_d256", "falcon-mamba-7b")):
            jobs.append((name, lambda name=name, arch=arch: bench_family(
                name, _prep(reduced(get_config(arch))), n_slots=8,
                block_steps=8, cache_len=128, n_requests=16, prompt_len=16,
                max_new=32, reps=2)))
        # resilience rows: overload shedding + seeded chaos with
        # mid-stream crash recovery (see module docstring)
        jobs.append(("overload_dense_gqa", lambda: bench_overload(
            "overload_dense_gqa", _tiny("qwen3-32b"), n_slots=8,
            block_steps=8, cache_len=64, n_requests=48, prompt_len=8,
            max_new=24, overload_xs=(2.0, 4.0))))
        jobs.append(("chaos_dense_gqa", lambda: bench_chaos(
            "chaos_dense_gqa", _tiny("qwen3-32b"), n_slots=8,
            block_steps=8, cache_len=64, n_requests=16, prompt_len=8,
            max_new=24, crash_after_block=3)))
        jobs.append(("chaos_ssm_mamba", lambda: bench_chaos(
            "chaos_ssm_mamba", _tiny("falcon-mamba-7b"), n_slots=8,
            block_steps=8, cache_len=64, n_requests=16, prompt_len=8,
            max_new=24, crash_after_block=3)))
    if args.only:
        jobs = [(n, fn) for n, fn in jobs if args.only in n]
        if not jobs:
            print(f"--only {args.only!r} matches no bench rows")
            return
    rows = [fn() for _, fn in jobs]
    results = {
        "bench": "serve_continuous_batching",
        "backend": jax.default_backend(),
        "rows": rows,
    }
    if args.only and os.path.exists(out):
        # merge mode: replace matching rows in the existing JSON in place,
        # append rows it didn't have, keep everything else untouched
        with open(out) as fh:
            old = json.load(fh)
        fresh = {r["name"]: r for r in rows}
        merged = [fresh.pop(r.get("name"), r) for r in old.get("rows", ())]
        merged += list(fresh.values())
        results = dict(old)
        results["rows"] = merged
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
