"""CI guard over the smoke benches: fail if the dispatch structure
regresses.

The engines' whole value proposition is their dispatch structure — one
compiled call per federation round (1/M under fused round blocks), one
compiled call and ONE host readback per M-token decode block in the
serving engine.  Wall-clock on a shared CI runner is too noisy to gate
on, but the dispatch counts are exact invariants, so this script asserts
them over the smoke JSON and exits non-zero on any regression (missing
row, extra dispatches, a per-token host sync that crept back in).

The bench kind is auto-detected from the file's ``bench`` field:

    python -m benchmarks.check_smoke BENCH_federation.smoke.json
    python -m benchmarks.check_smoke BENCH_serve.smoke.json
"""
from __future__ import annotations

import json
import sys

REQUIRED_ROWS = (
    "round_latency_k2",
    "mixed_width_bucketed_k2",
    "fused_rounds_m2",
    "sampled_cohort_c1_of_k2",
    "async_lagged_k2",
    "quarantine_1_poisoned",
    "gram_backend_k2",
)

REQUIRED_SERVE_ROWS = ("dense_gqa", "ssm_mamba", "chaos_dense_gqa",
                       "overload_dense_gqa")


class SkipCheck(Exception):
    """The file is not a smoke bench this script knows how to gate —
    report WHY and exit 0 instead of tracebacking on a KeyError."""


def check_serve(data: dict) -> list:
    """Serving smoke invariants: <= 1 dispatch (and <= 1 readback) per
    M decode tokens, zero per-token host syncs, and bit-identity with
    the legacy loop — all MEASURED by the bench, asserted here."""
    errors = []
    rows = {r["name"]: r for r in data.get("rows", ())}
    for name in REQUIRED_SERVE_ROWS:
        if name not in rows:
            errors.append(f"missing serve smoke row {name!r}")
    for r in data.get("rows", ()):
        name, m = r["name"], r.get("block_steps", 1)
        budget = round(1.0 / m, 4) + 1e-9
        for field in ("dispatches_per_token", "host_syncs_per_token"):
            if r.get(field, 0.0) > budget:
                errors.append(
                    f"{name}: {field}={r[field]} regressed (expected "
                    f"<= {round(1.0 / m, 4)} for M={m} blocks)")
        if r.get("per_token_extra_syncs", 0) != 0:
            errors.append(f"{name}: {r['per_token_extra_syncs']} per-token "
                          f"host syncs crept into the decode path")
        if r.get("tokens_mismatched_vs_naive", 0) != 0:
            errors.append(f"{name}: {r['tokens_mismatched_vs_naive']} "
                          f"requests diverged from the legacy-loop oracle")
        if r.get("speedup", 1.0) <= 0:
            errors.append(f"{name}: nonsensical speedup {r['speedup']}")
        if r.get("kind") == "chaos":
            errors += check_chaos_row(r)
        if r.get("kind") == "overload":
            errors += check_overload_row(r)
    return errors


def check_chaos_row(r: dict) -> list:
    """Resilience invariants under the seeded fault schedule, all
    MEASURED by the bench: every request in exactly one terminal state,
    no token derived from poisoned logits ever emitted (every stream is
    a prefix of the clean run's), completed requests bit-identical to
    the clean run, the guard actually fired, and the simulated crash was
    recovered through the serve snapshot."""
    name, errors = r["name"], []
    if not r.get("accounting_ok", False):
        errors.append(
            f"{name}: terminal-state accounting broken — counts "
            f"{r.get('counts')} do not sum to n_requests "
            f"{r.get('n_requests')} (a request ended in zero or two "
            f"terminal states)")
    if not r.get("prefix_clean_ok", False):
        errors.append(f"{name}: a poisoned/garbage token escaped into an "
                      f"emitted stream (prefix-of-clean-run check failed)")
    if not r.get("completed_match_clean", False):
        errors.append(f"{name}: a completed request diverged from the "
                      f"fault-free run")
    if r.get("faults_detected", 0) < 1:
        errors.append(f"{name}: NaN-poisoned schedule tripped no on-device "
                      f"fault flag — the guard is dead")
    if not r.get("resumed_after_crash", False):
        errors.append(f"{name}: simulated crash was not recovered via the "
                      f"serve snapshot")
    return errors


def check_overload_row(r: dict) -> list:
    """Graceful-degradation invariants: terminal-state accounting holds
    for every sweep, and at the highest overload factor the shedding
    run actually shed work and held TTFT p99 at or below the
    no-shedding baseline."""
    name, errors = r["name"], []
    sweeps = r.get("sweeps", {})
    if not sweeps:
        errors.append(f"{name}: overload row has no sweeps")
        return errors
    for label, sweep in sweeps.items():
        for mode in ("noshed", "shed"):
            if not sweep.get(mode, {}).get("accounting_ok", False):
                errors.append(
                    f"{name}[{label}/{mode}]: terminal-state accounting "
                    f"broken: {sweep.get(mode, {}).get('counts')}")
    top = max(sweeps, key=lambda k: float(k.lstrip('x')))
    if sweeps[top]["shed"]["counts"].get("shed", 0) < 1:
        errors.append(f"{name}[{top}]: overloaded run with deadlines shed "
                      f"nothing — admission control is dead")
    if not sweeps[top].get("shed_bounds_ttft_p99", False):
        errors.append(
            f"{name}[{top}]: shedding failed to hold TTFT p99 at or below "
            f"the no-shedding baseline "
            f"(shed {sweeps[top]['shed']['ttft_p99_ms']}ms vs noshed "
            f"{sweeps[top]['noshed']['ttft_p99_ms']}ms)")
    return errors


def check(data: dict) -> list:
    bench = data.get("bench")
    if bench is None:
        raise SkipCheck("no 'bench' field — not a smoke bench JSON "
                        "written by benchmarks/*.py")
    if "serve" in bench:
        return check_serve(data)
    if "federation" not in bench:
        raise SkipCheck(f"unknown bench kind {bench!r} (this script "
                        f"gates 'federation*' and '*serve*' benches)")
    errors = []
    named = [r for r in data.get("rows", ()) if isinstance(r, dict)
             and "name" in r]
    rows = {r["name"]: r for r in named}
    for name in REQUIRED_ROWS:
        if name not in rows:
            errors.append(f"missing smoke row {name!r}")
    for i, r in enumerate(data.get("rows", ())):
        if not (isinstance(r, dict) and "name" in r):
            print(f"skipping rows[{i}]: no 'name' field, not a bench row")
            continue
        name = r["name"]
        if r.get("engine_dispatches_per_round", 1) != 1:
            errors.append(
                f"{name}: engine dispatches/round regressed to "
                f"{r['engine_dispatches_per_round']} (expected 1)")
        m = r.get("block_rounds")
        if m:
            want = round(1.0 / m, 4)
            # dispatches_per_round is MEASURED (a counter on the compiled
            # block fn during the timed reps), so a driver that stops
            # fusing — or a participation path that adds per-round
            # dispatches — actually trips this
            for field in ("dispatches_per_round", "host_syncs_per_round"):
                if field in r and r[field] != want:
                    errors.append(f"{name}: {field}={r[field]} regressed "
                                  f"(expected {want} for M={m} blocks)")
            if r.get("per_round_dispatches_per_round", 1) != 1:
                errors.append(
                    f"{name}: per-round engine dispatches/round regressed "
                    f"to {r['per_round_dispatches_per_round']} "
                    f"(expected 1)")
        if "cost_vs_full" in r and r["cost_vs_full"] <= 0:
            errors.append(f"{name}: nonsensical cost_vs_full "
                          f"{r['cost_vs_full']}")
        if r.get("strategy") == "async":
            # robustness invariants, MEASURED by the bench: the global
            # state must stay finite even under a poisoned node, and the
            # device quarantine counters must agree exactly with the
            # bench's independent host-side count of poisoned report
            # attempts (a guard that misses or double-counts trips this)
            if not r.get("finite_global", False):
                errors.append(f"{name}: global state went non-finite "
                              f"under the async run")
            if r.get("quarantined") != r.get("expected_quarantined"):
                errors.append(
                    f"{name}: quarantine counters {r.get('quarantined')} "
                    f"!= host-side expected "
                    f"{r.get('expected_quarantined')}")
            if r.get("poison_nodes") and not any(r.get("quarantined", ())):
                errors.append(f"{name}: poisoned run quarantined nothing")
    return errors


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_federation.smoke.json"
    with open(path) as fh:
        data = json.load(fh)
    if not isinstance(data, dict):
        print(f"{path}: SKIP — top level is {type(data).__name__}, "
              f"not a bench result object")
        return 0
    try:
        errors = check(data)
    except SkipCheck as e:
        print(f"{path}: SKIP — {e}")
        return 0
    for e in errors:
        print(f"SMOKE BENCH REGRESSION: {e}", file=sys.stderr)
    if not errors:
        print(f"{path}: dispatch structure OK "
              f"({len(data.get('rows', ()))} rows)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
