"""CI guard over the federation smoke bench: fail if the dispatch
structure regresses.

The engine's whole value proposition is its dispatch structure — one
compiled call per round, 1/M per round under fused blocks, unchanged by
width bucketing and participation sampling.  Wall-clock on a shared CI
runner is too noisy to gate on, but the dispatch counts are exact
invariants, so this script asserts them over ``BENCH_federation.smoke.json``
and exits non-zero on any regression (missing row, extra dispatches, a
participation row that stopped fusing).

Run (after ``python -m benchmarks.federation_round --smoke``):

    python -m benchmarks.check_smoke BENCH_federation.smoke.json
"""
from __future__ import annotations

import json
import sys

REQUIRED_ROWS = (
    "round_latency_k2",
    "mixed_width_bucketed_k2",
    "fused_rounds_m2",
    "sampled_cohort_c1_of_k2",
    "gram_backend_k2",
)


def check(data: dict) -> list:
    errors = []
    rows = {r["name"]: r for r in data.get("rows", ())}
    for name in REQUIRED_ROWS:
        if name not in rows:
            errors.append(f"missing smoke row {name!r}")
    for r in data.get("rows", ()):
        name = r["name"]
        if r.get("engine_dispatches_per_round", 1) != 1:
            errors.append(
                f"{name}: engine dispatches/round regressed to "
                f"{r['engine_dispatches_per_round']} (expected 1)")
        m = r.get("block_rounds")
        if m:
            want = round(1.0 / m, 4)
            # dispatches_per_round is MEASURED (a counter on the compiled
            # block fn during the timed reps), so a driver that stops
            # fusing — or a participation path that adds per-round
            # dispatches — actually trips this
            for field in ("dispatches_per_round", "host_syncs_per_round"):
                if field in r and r[field] != want:
                    errors.append(f"{name}: {field}={r[field]} regressed "
                                  f"(expected {want} for M={m} blocks)")
            if r.get("per_round_dispatches_per_round", 1) != 1:
                errors.append(
                    f"{name}: per-round engine dispatches/round regressed "
                    f"to {r['per_round_dispatches_per_round']} "
                    f"(expected 1)")
        if "cost_vs_full" in r and r["cost_vs_full"] <= 0:
            errors.append(f"{name}: nonsensical cost_vs_full "
                          f"{r['cost_vs_full']}")
    return errors


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_federation.smoke.json"
    with open(path) as fh:
        data = json.load(fh)
    errors = check(data)
    for e in errors:
        print(f"SMOKE BENCH REGRESSION: {e}", file=sys.stderr)
    if not errors:
        print(f"{path}: dispatch structure OK "
              f"({len(data.get('rows', ()))} rows)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
