.PHONY: test bench bench-fed bench-fed-smoke train-smoke

# tier-1 verification (the CI entrypoint)
test:
	bash scripts/tier1.sh

# paper-claim benchmark table
bench:
	PYTHONPATH=src python -m benchmarks.run --quick

# sequential-loop vs node-stacked-engine round latency
# (writes BENCH_federation.json)
bench-fed:
	PYTHONPATH=src python -m benchmarks.federation_round

# tiny-config bench harness smoke (the CI invocation)
bench-fed-smoke:
	PYTHONPATH=src python -m benchmarks.federation_round --smoke

train-smoke:
	PYTHONPATH=src python -m repro.launch.train --tiny --rounds 2 \
		--local-steps 2 --batch 2 --seq 32 --anchors 6 --nodes 2
