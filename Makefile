.PHONY: test bench bench-fed bench-fed-smoke bench-serve \
	bench-serve-smoke bench-serve-chaos train-smoke

# tier-1 verification (the CI entrypoint)
test:
	bash scripts/tier1.sh

# paper-claim benchmark table
bench:
	PYTHONPATH=src python -m benchmarks.run --quick

# sequential-loop vs node-stacked-engine round latency
# (writes BENCH_federation.json)
bench-fed:
	PYTHONPATH=src python -m benchmarks.federation_round

# tiny-config bench harness smoke (the CI invocation; includes the fused
# M=2 round-block and sampled-cohort participation rows and writes
# BENCH_federation.smoke.json, uploaded as a CI artifact).  check_smoke
# fails the target if the dispatch structure regresses.
bench-fed-smoke:
	PYTHONPATH=src python -m benchmarks.federation_round --smoke
	PYTHONPATH=src python -m benchmarks.check_smoke BENCH_federation.smoke.json

# continuous-batching serving engine vs the legacy per-token loop
# (writes BENCH_serve.json: tokens/s, TTFT percentiles, dispatch
# structure, roofline prediction per model family)
bench-serve:
	PYTHONPATH=src python -m benchmarks.serve_bench

# tiny-config serving smoke (the CI invocation; writes
# BENCH_serve.smoke.json).  check_smoke fails the target if dispatches
# or host syncs per token exceed 1/M, if a per-token sync creeps back
# in, if the engine diverges from the legacy-loop oracle, or if the
# chaos/overload resilience rows regress (terminal-state accounting,
# no poisoned token emitted, crash recovered via snapshot, shedding
# bounding TTFT p99 under overload).
bench-serve-smoke:
	PYTHONPATH=src python -m benchmarks.serve_bench --smoke
	PYTHONPATH=src python -m benchmarks.check_smoke BENCH_serve.smoke.json

# re-run ONLY the resilience rows of the full serving bench (chaos
# fault-injection + overload shedding sweeps), merging them into an
# existing BENCH_serve.json without re-timing the throughput rows
bench-serve-chaos:
	PYTHONPATH=src python -m benchmarks.serve_bench --only chaos
	PYTHONPATH=src python -m benchmarks.serve_bench --only overload

train-smoke:
	PYTHONPATH=src python -m repro.launch.train --tiny --rounds 2 \
		--local-steps 2 --batch 2 --seq 32 --anchors 6 --nodes 2

# fused-block driver smoke: 4 rounds as two M=2 donated dispatches
train-smoke-fused:
	PYTHONPATH=src python -m repro.launch.train --tiny --rounds 4 \
		--block-size 2 --local-steps 2 --batch 2 --seq 32 --anchors 6 \
		--nodes 2
