"""Qwen3-32B (dense GQA with qk_norm) [hf:Qwen/Qwen3-8B family card]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-32b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (qwen3 family; 32B dims per assignment)",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    max_seq_len=131072,
    rope_theta=1e6,
    qk_norm=True,
    long_context_variant="sliding-window(8192) decode variant for long_500k "
                         "(flagged in DESIGN.md)",
)
