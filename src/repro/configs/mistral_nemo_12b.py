"""Mistral-Nemo-Base-2407 (12B dense, GQA) [hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-nemo-12b",
    family="dense",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    max_seq_len=131072,           # 128k context
    rope_theta=1e6,
    long_context_variant="sliding-window(8192) decode variant for long_500k "
                         "(paper config is full attention; flagged in DESIGN.md)",
)
