"""Falcon-Mamba-7B (attention-free Mamba-1 SSM) [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355 (Falcon Mamba); block per arXiv:2312.00752 (Mamba-1)",
    n_layers=64,
    d_model=4096,
    n_heads=0,                    # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    max_seq_len=1 << 20,
    ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2, chunk=128),
    long_context_variant="native: constant-size SSM state, O(1) decode memory",
)
