"""RecurrentGemma-9B (RG-LRU + local attention hybrid, 2:1) [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427 (Griffin/RecurrentGemma)",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,                 # GQA kv=1 (MQA) in the local-attention blocks
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,                 # 16 heads * 256 = 4096
    max_seq_len=1 << 20,
    rope_theta=1e4,
    rglru=RGLRUConfig(lru_width=4096, conv_kernel=4,
                      block_pattern=("recurrent", "recurrent", "attention"),
                      local_window=2048, chunk=128),
    long_context_variant="native: RG-LRU state + local attention window 2048",
)
