"""Config registry: ``get_config(arch_id)`` and smoke-test ``reduced()`` variants."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)

from repro.configs import (  # noqa: E402
    deepseek_v2_236b,
    falcon_mamba_7b,
    fedmm_base,
    llama4_scout_17b_a16e,
    mistral_nemo_12b,
    phi_3_vision_4_2b,
    qwen3_32b,
    recurrentgemma_9b,
    smollm_135m,
    whisper_large_v3,
    yi_6b,
)

ASSIGNED_ARCHS = (
    "mistral-nemo-12b",
    "falcon-mamba-7b",
    "recurrentgemma-9b",
    "yi-6b",
    "phi-3-vision-4.2b",
    "whisper-large-v3",
    "smollm-135m",
    "llama4-scout-17b-a16e",
    "deepseek-v2-236b",
    "qwen3-32b",
)

_REGISTRY = {
    c.arch_id: c
    for c in (
        mistral_nemo_12b.CONFIG,
        falcon_mamba_7b.CONFIG,
        recurrentgemma_9b.CONFIG,
        yi_6b.CONFIG,
        phi_3_vision_4_2b.CONFIG,
        whisper_large_v3.CONFIG,
        smollm_135m.CONFIG,
        llama4_scout_17b_a16e.CONFIG,
        deepseek_v2_236b.CONFIG,
        qwen3_32b.CONFIG,
        fedmm_base.CONFIG,
        fedmm_base.SMALL,
    )
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """CPU smoke-test variant of the same family: <=2 layers, d_model<=512,
    <=4 experts, small vocab/context — per the brief."""
    kw = dict(
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        max_seq_len=256,
        dtype="float32",
    )
    if cfg.family == "ssm":
        kw.update(n_heads=0, n_kv_heads=0, d_ff=0)
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk=16)
    if cfg.family == "hybrid":
        kw["n_layers"] = 3  # one full (recurrent, recurrent, attention) group
        kw["n_kv_heads"] = 1
        kw["rglru"] = dataclasses.replace(
            cfg.rglru, lru_width=256, local_window=64, chunk=16)
    if cfg.family == "moe":
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_ff_expert=256)
        kw["d_ff"] = 256
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=64,
                              q_lora_rank=48 if cfg.mla.q_lora_rank else 0,
                              rope_head_dim=32, nope_head_dim=64, v_head_dim=64)
    if cfg.family == "audio":
        kw.update(n_encoder_layers=2, encoder_seq_len=32, encoder_embed_dim=256,
                  max_seq_len=64)
    if cfg.family == "vlm":
        kw.update(n_image_tokens=8, image_embed_dim=64)
    if cfg.sliding_window:
        kw["sliding_window"] = 64
    if cfg.attention_chunk:
        kw["attention_chunk"] = 64
    return cfg.with_(**kw)


__all__ = [
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "InputShape",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "RGLRUConfig",
    "SSMConfig",
    "get_config",
    "list_archs",
    "reduced",
]
