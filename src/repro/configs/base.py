"""Model / run configuration dataclasses.

Every assigned architecture gets one module in this package defining a
``ModelConfig`` with the exact published hyperparameters (citation in
``source``).  ``reduced()`` produces the CPU smoke-test variant of the same
family (<=2 layers, d_model<=512, <=4 experts) mandated by the brief.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    top_k: int = 1
    num_shared_experts: int = 0   # always-on experts
    d_ff_expert: int = 0          # per-expert hidden dim
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention dims [arXiv:2405.04434]."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank q projection
    rope_head_dim: int = 64       # decoupled rope key dim
    nope_head_dim: int = 128      # per-head non-rope dim
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 block dims [arXiv:2312.00752 / falcon-mamba arXiv:2410.05355]."""
    state_dim: int = 16
    conv_kernel: int = 4
    expand: int = 2               # d_inner = expand * d_model
    dt_rank: int = 0              # 0 => ceil(d_model/16)
    chunk: int = 128              # chunked associative scan length


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU dims [arXiv:2402.19427]."""
    lru_width: int = 0            # 0 => d_model
    conv_kernel: int = 4
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    local_window: int = 2048
    chunk: int = 128


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    source: str                   # citation
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    max_seq_len: int = 131072
    rope_theta: float = 1e6
    qk_norm: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # attention variants
    sliding_window: int = 0       # 0 => full attention; >0 => SWA width
    attention_chunk: int = 0      # llama4-style chunked local attention
    # family extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # enc-dec (audio)
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0      # frames after the (stubbed) conv frontend
    encoder_embed_dim: int = 0    # stub frontend output dim
    # vlm
    n_image_tokens: int = 0       # patch embeds prepended to the text sequence
    image_embed_dim: int = 0      # stub vision-encoder output dim
    # notes for DESIGN.md / dry-run skips
    long_context_variant: str = ""  # how long_500k decode is supported
    skip_shapes: Tuple[str, ...] = ()

    # ------------------------------------------------------------------
    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def param_count(self) -> int:
        """Analytic total parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d + (0 if self.tie_embeddings else v * d)
        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = self._attn_params() + 3 * d * f + 2 * d
        elif self.family == "moe":
            m = self.moe
            dense_ff = 3 * d * m.d_ff_expert * m.num_shared_experts
            expert_ff = 3 * d * m.d_ff_expert * m.num_experts
            router = d * m.num_experts
            per_layer = self._attn_params() + dense_ff + expert_ff + router + 2 * d
        elif self.family == "ssm":
            s = self.ssm
            di = s.expand * d
            dtr = s.dt_rank or -(-d // 16)
            per_layer = (2 * d * di + di * s.conv_kernel
                         + di * (dtr + 2 * s.state_dim) + dtr * di
                         + di * s.state_dim + di + di * d + d)
        elif self.family == "hybrid":
            r = self.rglru
            w = r.lru_width or d
            rec = 2 * d * w + 2 * w * r.conv_kernel + 2 * w * w // 1 + w * d
            att = self._attn_params()
            pat = r.block_pattern
            n_rec = sum(1 for i in range(self.n_layers) if pat[i % len(pat)] == "recurrent")
            n_att = self.n_layers - n_rec
            per_layer = 0  # handled below
            blocks = n_rec * (rec + 3 * d * f + 2 * d) + n_att * (att + 3 * d * f + 2 * d)
            return emb + blocks + d
        elif self.family == "audio":
            enc = self.n_encoder_layers * (self._attn_params() + 2 * d * f + 2 * d)
            dec = self.n_layers * (2 * self._attn_params() + 2 * d * f + 3 * d)
            return emb + enc + dec + 2 * d
        return emb + self.n_layers * per_layer + d

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            qd = (d * self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                  if m.q_lora_rank == 0 else
                  d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (m.nope_head_dim + m.rope_head_dim))
            kvd = d * (m.kv_lora_rank + m.rope_head_dim)
            kvu = m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
            o = self.n_heads * m.v_head_dim * d
            return qd + kvd + kvu + o
        q = d * self.n_heads * self.head_dim
        kv = 2 * d * self.n_kv_heads * self.head_dim
        o = self.n_heads * self.head_dim * d
        return q + kv + o

    @property
    def active_param_count(self) -> int:
        """Active params per token (= param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count
        m = self.moe
        d = self.d_model
        inactive = 3 * d * m.d_ff_expert * (m.num_experts - m.top_k) * self.n_layers
        return self.param_count - inactive


# ----------------------------------------------------------------------
# Assigned input shapes (fixed across architectures).
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
