"""Whisper-large-v3 (encoder-decoder audio) [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a stub — ``input_specs()`` supplies
precomputed frame embeddings (1500 frames, d=1280) fed to the encoder stack.
Vocab padded 51866 -> 51872 (multiple of 32) for clean vocab sharding; the
original size is recorded here.
"""
from repro.configs.base import ModelConfig

ORIGINAL_VOCAB = 51866

CONFIG = ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356 (Whisper); large-v3 card",
    n_layers=32,                  # decoder layers (encoder: n_encoder_layers)
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,                # MHA
    d_ff=5120,
    vocab_size=51872,             # padded from 51866 for sharding
    head_dim=64,                  # 20 * 64 = 1280
    max_seq_len=448,
    rope_theta=0.0,               # whisper uses learned/sinusoidal positions
    n_encoder_layers=32,
    encoder_seq_len=1500,
    encoder_embed_dim=1280,
    skip_shapes=("long_500k",),   # enc-dec audio: no 500k decode regime (DESIGN.md)
    long_context_variant="skipped: encoder-decoder audio model (1500-frame "
                         "encoder, ~448-token decoder)",
)
