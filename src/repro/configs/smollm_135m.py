"""SmolLM-135M (llama-architecture small dense) [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    head_dim=64,                  # 9 * 64 = 576
    max_seq_len=2048,
    rope_theta=1e4,
    tie_embeddings=True,
    long_context_variant="sliding-window(8192) decode variant for long_500k "
                         "(flagged in DESIGN.md)",
)
