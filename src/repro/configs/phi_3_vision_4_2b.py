"""Phi-3-Vision-128k (phi3-mini backbone + CLIP frontend stub)
[hf:microsoft/Phi-3-vision-128k-instruct].

Per the brief, only the transformer backbone is implemented; the CLIP ViT
vision encoder is a stub — ``input_specs()`` supplies precomputed patch
embeddings (image_embed_dim=1024, CLIP ViT-L/14) which the trainable
projector (the paper's adapter W_mk) maps into d_model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b",
    family="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,                # MHA (kv=32)
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,                  # 32 * 96 = 3072
    max_seq_len=131072,
    rope_theta=1e4,
    n_image_tokens=576,           # 24x24 CLIP patch grid
    image_embed_dim=1024,
    long_context_variant="sliding-window(8192) decode variant for long_500k "
                         "(flagged in DESIGN.md)",
)
