"""The paper's own global homogeneous multimodal transformer.

The paper initializes the global model from a pretrained VLM backbone; we use
a llama-style dense decoder at ~0.4B scale ("fedmm-base") as the federation's
global model, plus a ~100M "fedmm-small" used by the end-to-end training
example.  Modality tokenizer dims follow the paper's choices (DINOv3 /
DNABERT / TabPFN / Llama as frozen featurizers — stubbed per DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="fedmm-base",
    family="dense",
    source="this paper (global homogeneous transformer, VLM-init scale)",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=2816,
    vocab_size=32768,
    head_dim=64,
    max_seq_len=4096,
    rope_theta=1e4,
    long_context_variant="sliding-window(8192) decode variant",
)

SMALL = CONFIG.with_(
    arch_id="fedmm-small",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=16384,
)

# Frozen per-modality tokenizer output dims (the paper's phi_m); stub values
# follow the real tokenizers' embedding widths.
MODALITY_TOKENIZER_DIMS = {
    "image": 1024,     # DINOv3 ViT-L [arXiv:2508.10104]
    "text": 2048,      # Llama small variant [arXiv:2302.13971]
    "genetics": 768,   # DNABERT [Bioinformatics 37(15)]
    "tabular": 192,    # TabPFN feature embeddings [Nature 637]
}
