"""Llama-4-Scout-17B-16E (MoE, 16 routed experts top-1 + 1 shared, early fusion)
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,                    # per-expert hidden dim
    vocab_size=202048,
    head_dim=128,
    max_seq_len=1 << 20,          # 10M advertised; 1M here
    rope_theta=5e5,
    attention_chunk=8192,         # llama4 chunked local attention (iRoPE)
    moe=MoEConfig(num_experts=16, top_k=1, num_shared_experts=1,
                  d_ff_expert=8192, capacity_factor=1.25),
    long_context_variant="native: chunked local attention (8192) per model card",
)
