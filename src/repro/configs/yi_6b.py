"""Yi-6B (llama-architecture dense, GQA) [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-6b",
    family="dense",
    source="arXiv:2403.04652 (Yi)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    max_seq_len=4096,
    rope_theta=5e6,
    long_context_variant="sliding-window(8192) decode variant for long_500k "
                         "(flagged in DESIGN.md)",
)
