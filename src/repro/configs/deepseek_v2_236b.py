"""DeepSeek-V2 236B (MLA kv_lora=512, 2 shared + 160 routed experts top-6)
[arXiv:2405.04434]."""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434 (DeepSeek-V2)",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,               # MLA: all heads read the shared latent KV
    d_ff=1536,                    # per-expert hidden dim
    vocab_size=102400,
    head_dim=128,
    max_seq_len=131072,
    rope_theta=1e4,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared_experts=2,
                  d_ff_expert=1536, capacity_factor=1.25),
    long_context_variant="native-ish: MLA compressed KV cache (576 B/token "
                         "bf16) keeps 500k decode cache at ~604 MB",
)
