"""AdamW + LR schedules, from scratch (no optax in the environment).

Works on partitioned pytrees: ``None`` leaves (frozen params under the
paper's GeoLoRA protocol) are passed through untouched, so optimizer state
is only materialised for the trainable side-cars — the memory win that
makes federated fine-tuning of a huge global model feasible on nodes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def _map(f, *trees):
    return jax.tree.map(
        lambda *xs: None if xs[0] is None else f(*xs), *trees,
        is_leaf=lambda x: x is None)


@dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    schedule: Optional[Callable] = None      # step -> multiplier
    # global-ROUND schedule (federated drivers): multiplier keyed on the
    # round counter the engine threads through the scan carry (the opt
    # state gains a "round" entry, bumped once per federated round by the
    # round executor), so warmup/cosine ACROSS fused round blocks works
    # without re-jitting per round.  Composes with ``schedule``.
    round_schedule: Optional[Callable] = None    # round -> multiplier

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        state = {"m": _map(zeros, params), "v": _map(zeros, params),
                 "step": jnp.zeros((), jnp.int32)}
        if self.round_schedule is not None:
            state["round"] = jnp.zeros((), jnp.int32)
        return state

    def update(self, grads, state, params):
        step = state["step"] + 1
        if self.grad_clip > 0:
            leaves = [g for g in jax.tree.leaves(grads) if g is not None]
            gnorm = jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum()
                                 for g in leaves))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = _map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        m = _map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                 state["m"], grads)
        v = _map(lambda vv, g: b2 * vv + (1 - b2)
                 * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        mhat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
        vhat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))
        lr = self.lr * (self.schedule(step) if self.schedule else 1.0)
        if self.round_schedule is not None and "round" in state:
            lr = lr * self.round_schedule(state["round"])

        def upd(p, mm, vv):
            u = (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = _map(upd, params, m, v)
        new_state = {"m": m, "v": v, "step": step}
        if "round" in state:
            new_state["round"] = state["round"]
        return new_params, new_state


def warmup_cosine(warmup: int, total: int, floor: float = 0.1) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return sched
