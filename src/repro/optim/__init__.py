from repro.optim.adamw import AdamW, warmup_cosine
