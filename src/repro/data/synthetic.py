"""Synthetic unpaired multimodal task with a shared latent concept space.

Simulates the paper's hospital federation (repro band 2/5 — no real TCGA /
MIMIC access): ``n_classes`` disease concepts live in a latent space; a
sample of class c in modality m is an independent draw around prototype c
pushed through a fixed modality-specific map.  Nodes hold ONE modality each
and never share samples; the public anchor set holds a few *unpaired* draws
per class per modality ("same medical concept, not same patient").

Because every modality is a different view of the same latent geometry, the
cross-modal Gram matrices are alignable — which is the hypothesis the
paper's CKA regulariser operationalises.  A ``corrupt`` flag yields nodes
whose data is latent-free noise (for validating precision-weighted
aggregation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class SyntheticMultimodal:
    n_classes: int = 8
    d_latent: int = 32
    d_raw: int = 64
    noise: float = 0.25
    seed: int = 0
    modalities: Tuple[str, ...] = ("image", "text", "genetics", "tabular")

    def _keys(self):
        return jax.random.split(jax.random.PRNGKey(self.seed), 4)

    def prototypes(self) -> Array:
        k, *_ = self._keys()
        return jax.random.normal(k, (self.n_classes, self.d_latent))

    def modality_map(self, modality: str):
        """Public accessor for the fixed modality map (w, b) — the
        node-stacked engine bakes these in as per-node constants so data
        sampling can run inside the compiled round."""
        return self._modality_map(modality)

    def _modality_map(self, modality: str):
        _, k, *_ = self._keys()
        km = jax.random.fold_in(k, hash(modality) % (2 ** 31))
        k1, k2 = jax.random.split(km)
        w = jax.random.normal(k1, (self.d_latent, self.d_raw)) \
            * self.d_latent ** -0.5
        b = 0.3 * jax.random.normal(k2, (self.d_raw,))
        return w, b

    def sample(self, key, modality: str, n: int, *,
               class_probs: Optional[Array] = None,
               corrupt: bool = False) -> Tuple[Array, Array]:
        """-> raw (n, d_raw), labels (n,). ``corrupt`` nodes emit pure noise
        with random labels (no latent structure)."""
        k1, k2, k3 = jax.random.split(key, 3)
        if corrupt:
            raw = jax.random.normal(k2, (n, self.d_raw))
            labels = jax.random.randint(k1, (n,), 0, self.n_classes)
            return raw, labels
        probs = (class_probs if class_probs is not None
                 else jnp.full((self.n_classes,), 1.0 / self.n_classes))
        labels = jax.random.categorical(
            k1, jnp.log(jnp.maximum(probs, 1e-9)), shape=(n,))
        latent = self.prototypes()[labels] \
            + self.noise * jax.random.normal(k2, (n, self.d_latent))
        w, b = self._modality_map(modality)
        raw = jnp.tanh(latent @ w + b) \
            + 0.05 * jax.random.normal(k3, (n, self.d_raw))
        return raw, labels

    def sample_in_scan(self, key, mod_w: Array, mod_b: Array, n: int,
                       corrupt: Array, *, mod2_w: Optional[Array] = None,
                       mod2_b: Optional[Array] = None):
        """Traceable twin of ``sample`` for compiled round/block bodies
        (vmap over nodes, lax.scan over steps and rounds): the modality map
        is passed as arrays instead of looked up by name, and ``corrupt``
        is a traced selector — both the clean and corrupt branches are
        drawn from the SAME key splits as ``sample`` and selected per node,
        so one program serves every node type with reference-identical RNG
        streams.  With ``mod2_w/b`` (bridge nodes) the identical latent and
        output-noise draws are pushed through the second modality map,
        reproducing the reference's re-sample-with-same-key pairing.

        -> (raw (n, d_raw), labels (n,), raw2 (n, d_raw) | None)
        """
        k1, k2, k3 = jax.random.split(key, 3)
        log_probs = jnp.log(jnp.full((self.n_classes,),
                                     1.0 / self.n_classes))
        labels_c = jax.random.categorical(k1, log_probs, shape=(n,))
        latent = self.prototypes()[labels_c] \
            + self.noise * jax.random.normal(k2, (n, self.d_latent))
        out_noise = 0.05 * jax.random.normal(k3, (n, self.d_raw))
        raw_c = jnp.tanh(latent @ mod_w + mod_b) + out_noise
        raw_x = jax.random.normal(k2, (n, self.d_raw))
        labels_x = jax.random.randint(k1, (n,), 0, self.n_classes)
        raw = jnp.where(corrupt, raw_x, raw_c)
        labels = jnp.where(corrupt, labels_x, labels_c)
        raw2 = (jnp.tanh(latent @ mod2_w + mod2_b) + out_noise
                if mod2_w is not None else None)
        return raw, labels, raw2

    def anchor_set(self, key, n_per_class: int = 4
                   ) -> Dict[str, Tuple[Array, Array]]:
        """Public anchors: for each modality, n_per_class *independent*
        (unpaired) draws per class, class-sorted so Gram rows correspond
        across modalities at the concept level."""
        out = {}
        labels = jnp.repeat(jnp.arange(self.n_classes), n_per_class)
        for i, m in enumerate(self.modalities):
            km = jax.random.fold_in(key, i)
            latent = self.prototypes()[labels] + self.noise * \
                jax.random.normal(km, (labels.shape[0], self.d_latent))
            w, b = self._modality_map(m)
            kn = jax.random.fold_in(km, 1)
            raw = jnp.tanh(latent @ w + b) \
                + 0.05 * jax.random.normal(kn, (labels.shape[0], self.d_raw))
            out[m] = (raw, labels)
        return out
