"""Frozen per-modality tokenizer stubs (the paper's phi_m).

The paper uses pretrained frozen tokenizers (DINOv3 for images, DNABERT for
genetics, TabPFN for tabular, Llama for text).  Those checkpoints are a data
gate (repro band 2/5), so we simulate them: a deterministic frozen random
featurizer mapping raw modality vectors to L tokens of width d_m.  Crucially
it PRESERVES the latent class geometry (a smooth injective map of the raw
space), which is exactly the property the paper's platonic-convergence
argument relies on — so the CKA-alignment math is exercised faithfully.

Tokenizers are never trained and never shipped (paper: "frozen and not
shared in the federation").
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class FrozenTokenizer:
    """phi_m: raw (N, d_raw) -> tokens (N, L, d_m)."""
    modality: str
    d_raw: int
    n_tokens: int
    d_out: int
    seed: int = 0

    def _weights(self):
        k = jax.random.PRNGKey(hash((self.modality, self.seed)) % (2 ** 31))
        k1, k2, k3 = jax.random.split(k, 3)
        w1 = jax.random.normal(k1, (self.d_raw, self.n_tokens, self.d_out)) \
            * self.d_raw ** -0.5
        b1 = 0.1 * jax.random.normal(k2, (self.n_tokens, self.d_out))
        w2 = jax.random.normal(k3, (self.d_out, self.d_out)) * self.d_out ** -0.5
        return w1, b1, w2

    def __call__(self, raw: Array) -> Array:
        w1, b1, w2 = self._weights()
        h = jnp.einsum("nd,dlo->nlo", raw.astype(jnp.float32), w1) + b1
        return jnp.tanh(h) @ w2                      # (N, L, d_out)

    def padded_weights(self, width: int):
        """Weights zero-padded to token width ``width`` >= d_out, for the
        node-stacked engine (one program over heterogeneous tokenizers).
        Zero padding is exact: padded inputs stay 0 through tanh, padded
        w2 rows/cols contribute 0, so outputs match the unpadded tokenizer
        on the first d_out channels and are 0 beyond."""
        w1, b1, w2 = self._weights()
        pad = width - self.d_out
        if pad < 0:
            raise ValueError(f"width {width} < d_out {self.d_out}")
        w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pad)))
        b1 = jnp.pad(b1, ((0, 0), (0, pad)))
        w2 = jnp.pad(w2, ((0, pad), (0, pad)))
        return w1, b1, w2


def default_tokenizers(modality_dims: dict, d_raw: int, n_tokens: int = 16,
                       seed: int = 0) -> dict:
    """One frozen tokenizer per modality with its published embedding width
    (see configs.fedmm_base.MODALITY_TOKENIZER_DIMS)."""
    return {m: FrozenTokenizer(m, d_raw, n_tokens, d, seed=seed)
            for m, d in modality_dims.items()}
