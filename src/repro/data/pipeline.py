"""Data pipeline: deterministic synthetic LM token streams + host batching
with device placement, used by the example drivers and benchmarks.

(Real deployments would swap ``SyntheticLMStream`` for a tokenised corpus
reader; the interface — ``__iter__`` yielding ready batches — stays.)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class SyntheticLMStream:
    """Markov-ish synthetic token stream: structured enough that a model can
    reduce loss, deterministic per seed."""
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        # low-rank transition structure => learnable bigram statistics
        rank = 8
        u = rng.standard_normal((self.vocab_size, rank))
        v = rng.standard_normal((rank, self.vocab_size))
        logits = (u @ v) / np.sqrt(rank)
        probs = np.exp(logits - logits.max(1, keepdims=True))
        probs /= probs.sum(1, keepdims=True)
        cumprobs = probs.cumsum(1)
        while True:
            toks = np.empty((self.batch_size, self.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(0, self.vocab_size, self.batch_size)
            r = rng.random((self.batch_size, self.seq_len))
            for t in range(self.seq_len):
                rows = cumprobs[toks[:, t]]
                toks[:, t + 1] = (rows < r[:, t:t + 1]).sum(1)
            # host (numpy) batches: consumers stack whole rounds or blocks
            # and ship ONE device transfer per leaf, so yielding device
            # arrays here would only add per-batch round-trips
            yield {"tokens": toks[:, :-1].copy(),
                   "labels": toks[:, 1:].copy()}


def shard_batch(batch: dict, sharding) -> dict:
    """Place a host batch onto devices with the given NamedSharding."""
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)


def stack_block_batches(grid, sharding=None):
    """``grid[m][e][k]`` per-(round, step, node) batch pytrees -> ONE pytree
    with leading ``(M, E, K, ...)`` axes, ready for the fused-round
    executor's scan.  Leaves are staged host-side (numpy; device-array
    leaves are pulled back first, so streams should yield numpy) and the
    whole block ships as one async ``device_put`` per leaf instead of
    M*E*K small transfers."""
    def stack(*xs):
        return np.stack([np.asarray(x) for x in xs])

    block = jax.tree.map(
        stack, *[jax.tree.map(stack, *[jax.tree.map(stack, *nodes)
                                       for nodes in rnd])
                 for rnd in grid])
    put = (jnp.asarray if sharding is None
           else lambda x: jax.device_put(x, sharding))
    return jax.tree.map(put, block)


@dataclass
class BlockStager:
    """Host-side staging for fused multi-round blocks: pulls M rounds x E
    steps from the K per-node streams and leaf-stacks them into an
    ``(M, E, K, ...)`` device tensor.  Drivers double-buffer by calling
    ``next_block`` for block N+1 right after dispatching block N — the
    host staging work overlaps the in-flight device block, and because
    ``device_put`` is async nothing here blocks on the device.

    Streams are consumed in (round, step, node) order, identical to the
    per-round driver's consumption order, so data is block-size-invariant.
    """
    streams: list
    local_steps: int
    block_rounds: int
    sharding: object = None

    def next_block(self, m: Optional[int] = None):
        m = self.block_rounds if m is None else m
        grid = [[[next(s) for s in self.streams]
                 for _ in range(self.local_steps)] for _ in range(m)]
        return stack_block_batches(grid, self.sharding)


def make_lm_batch(key, cfg: ModelConfig, batch: int, seq: int) -> dict:
    toks = jax.random.randint(key, (batch, seq + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
