"""Data pipeline: deterministic synthetic LM token streams + host batching
with device placement, used by the example drivers and benchmarks.

(Real deployments would swap ``SyntheticLMStream`` for a tokenised corpus
reader; the interface — ``__iter__`` yielding ready batches — stays.)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class SyntheticLMStream:
    """Markov-ish synthetic token stream: structured enough that a model can
    reduce loss, deterministic per seed."""
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        # low-rank transition structure => learnable bigram statistics
        rank = 8
        u = rng.standard_normal((self.vocab_size, rank))
        v = rng.standard_normal((rank, self.vocab_size))
        logits = (u @ v) / np.sqrt(rank)
        probs = np.exp(logits - logits.max(1, keepdims=True))
        probs /= probs.sum(1, keepdims=True)
        cumprobs = probs.cumsum(1)
        while True:
            toks = np.empty((self.batch_size, self.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(0, self.vocab_size, self.batch_size)
            r = rng.random((self.batch_size, self.seq_len))
            for t in range(self.seq_len):
                rows = cumprobs[toks[:, t]]
                toks[:, t + 1] = (rows < r[:, t:t + 1]).sum(1)
            yield {"tokens": jnp.asarray(toks[:, :-1]),
                   "labels": jnp.asarray(toks[:, 1:])}


def shard_batch(batch: dict, sharding) -> dict:
    """Place a host batch onto devices with the given NamedSharding."""
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)


def make_lm_batch(key, cfg: ModelConfig, batch: int, seq: int) -> dict:
    toks = jax.random.randint(key, (batch, seq + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
