from repro.data.pipeline import SyntheticLMStream, make_lm_batch, shard_batch
from repro.data.synthetic import SyntheticMultimodal
from repro.data.tokenizers import FrozenTokenizer, default_tokenizers
