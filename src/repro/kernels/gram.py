"""Pallas TPU kernel: pairwise cosine-similarity Gram matrix (paper Eq. 1).

The anchor Gram matrix is recomputed every local step (it sits inside the
CKA loss), so on TPU it deserves an MXU-tiled kernel: the (B, D) pooled
anchor block is tiled into VMEM (bm x D) x (bn x D) panels; each grid cell
normalises its rows in-register and issues one (bm, D) @ (D, bn) MXU
contraction.  D stays untiled: pooled activations are at most d_model=5120
wide => a 128 x 5120 f32 panel is 2.6 MB, comfortably inside the ~16 MB
VMEM budget, and keeping the contraction dim whole avoids a second
accumulation loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _gram_kernel(x_ref, y_ref, o_ref, *, eps: float):
    xi = x_ref[...].astype(jnp.float32)                    # (bm, D)
    xj = y_ref[...].astype(jnp.float32)                    # (bn, D)
    ni = jax.lax.rsqrt(jnp.maximum((xi * xi).sum(-1, keepdims=True), eps))
    nj = jax.lax.rsqrt(jnp.maximum((xj * xj).sum(-1, keepdims=True), eps))
    o_ref[...] = ((xi * ni) @ (xj * nj).T).astype(o_ref.dtype)


def cosine_gram_pallas(x: Array, *, block: int = 128, eps: float = 1e-8,
                       interpret: bool = False) -> Array:
    """(B, D) -> (B, B). Rows padded to the block size; padding rows have
    zero norm and are sliced away (their eps-guarded values never leak)."""
    b, d = x.shape
    bm = min(block, max(8, b))
    pad = (-b) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n = x.shape[0]
    grid = (n // bm, n // bm)
    out = pl.pallas_call(
        functools.partial(_gram_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, x)
    return out[:b, :b]
