"""jit'd dispatch wrappers: Pallas kernels on TPU, jnp oracles elsewhere.

``use_pallas(True/False)`` or the REPRO_USE_PALLAS env var forces a path;
default: Pallas on TPU backends, reference on CPU (where non-interpret
Pallas cannot lower).  ``interpret=True`` runs the Pallas kernel body in
Python on CPU — how tests validate kernels in this container.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gram import cosine_gram_pallas
from repro.kernels.lora_matmul import lora_matmul_pallas
from repro.kernels.selective_scan import selective_scan_pallas

Array = jax.Array
_FORCE: Optional[bool] = None


def use_pallas(flag: Optional[bool]) -> None:
    global _FORCE
    _FORCE = flag


def _pallas_active() -> bool:
    if _FORCE is not None:
        return _FORCE
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def cosine_gram(x: Array, interpret: bool = False) -> Array:
    if _pallas_active() or interpret:
        return cosine_gram_pallas(x, interpret=interpret or
                                  jax.default_backend() != "tpu")
    return ref.cosine_gram_ref(x)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def lora_matmul(x: Array, w: Array, a: Array, b: Array,
                scale: float = 1.0, interpret: bool = False) -> Array:
    if _pallas_active() or interpret:
        return lora_matmul_pallas(x, w, a, b, scale=scale,
                                  interpret=interpret or
                                  jax.default_backend() != "tpu")
    return ref.lora_matmul_ref(x, w, a, b, scale)


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "n_rep", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    scale: Optional[float] = None, n_rep: int = 1,
                    interpret: bool = False) -> Array:
    if _pallas_active() or interpret:
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale, n_rep=n_rep,
            interpret=interpret or jax.default_backend() != "tpu")
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=0)
        v = jnp.repeat(v, n_rep, axis=0)
    return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_scan(da: Array, dbx: Array, h0: Array, interpret: bool = False):
    if _pallas_active() or interpret:
        return selective_scan_pallas(
            da, dbx, h0,
            interpret=interpret or jax.default_backend() != "tpu")
    return ref.selective_scan_ref(da, dbx, h0)
