"""Pallas TPU kernel: single-token decode attention over the packed KV pool.

Decode queries are one token per slot, so the flash kernel's (bq, dh)
query panel degenerates to a single sublane at bq=1 — almost the whole
MXU tile is padding.  This kernel instead packs the ``rep = H // KV``
query heads that share a KV head into the SUBLANE dimension: the grid is
``(S slots, KV heads, nkv KV blocks)`` and each cell contracts a
(rep, dh) query panel against a (bkv, dh) KV panel, so the score tile is
(rep, bkv) and no panel row is wasted on sequence padding.  The GQA
grouping itself is the same zero-copy ``index_map`` trick as
``flash_attention.py``: q is viewed as (S, KV, rep, dh) and the KV
BlockSpec indexes head ``g`` of the un-repeated (S, C, KV, dh) pool — K/V
are never materially repeated in HBM.

Masking is positional, matching the serving cache layout exactly: every
pool entry carries its absolute position (``kv_pos``; empty / padded
slots hold a huge sentinel) and each slot carries its own current
position ``q_pos``, so one rule covers causal validity, partially-filled
slots, AND ring-buffer sliding windows:

    ok = (kv_pos <= q_pos) & (q_pos - kv_pos < window)

with ``window = cache_len`` for non-windowed caches (a linear buffer
never holds a position older than cache_len).  The KV axis is innermost
so the online-softmax running state (m, l, acc) lives in VMEM scratch
across sequential KV steps, exactly like the flash kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, window: int,
                   nkv: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (rep, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (bkv, dh)
    s = q @ k.T                                        # (rep, bkv)
    qp = qpos_ref[0]                                   # scalar int32
    kp = kpos_ref[...]                                 # (1, bkv)
    # one mask covers causality, empty (sentinel-pos) slots and the ring
    # window; padded cache tails carry the sentinel so they fail kp <= qp
    ok = (kp <= qp) & (qp - kp < window)
    s = jnp.where(ok, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    # explicit mask on p: an all-masked block would otherwise exp(0)=1
    # while m is still NEG_INF
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + p @ v_ref[0, :, 0].astype(jnp.float32)

    @pl.when(ki == nkv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q: Array, k: Array, v: Array, q_pos: Array,
                            kv_pos: Array, *, window: int = 0,
                            scale: float = None, bkv: int = 128,
                            interpret: bool = False) -> Array:
    """q: (S, H, dh); k, v: (S, C, KV, dh); q_pos: (S,); kv_pos: (S, C).

    H = KV * rep, with query head h attending to KV head h // rep (the
    layout ``blockwise_attention`` and the serving cache pool share).
    ``window`` is the sliding-window width; 0 means un-windowed (masked
    internally as window = C, the most a linear buffer can hold).
    Returns (S, H, dh).
    """
    s_slots, h, dh = q.shape
    c, n_kv = k.shape[1], k.shape[2]
    rep = h // n_kv
    scale = scale if scale is not None else dh ** -0.5
    window = window or c
    bkv = min(bkv, c)
    pad = (-c) % bkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)),
                         constant_values=jnp.iinfo(jnp.int32).max // 2)
    nkv = (c + pad) // bkv
    qg = q.reshape(s_slots, n_kv, rep, dh)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          nkv=nkv),
        grid=(s_slots, n_kv, nkv),
        in_specs=[
            pl.BlockSpec((1,), lambda b, g, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, rep, dh), lambda b, g, j: (b, g, 0, 0)),
            pl.BlockSpec((1, bkv, 1, dh), lambda b, g, j: (b, j, g, 0)),
            pl.BlockSpec((1, bkv, 1, dh), lambda b, g, j: (b, j, g, 0)),
            pl.BlockSpec((1, bkv), lambda b, g, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dh), lambda b, g, j: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s_slots, n_kv, rep, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos.astype(jnp.int32), qg, k, v, kv_pos.astype(jnp.int32))
    return out.reshape(s_slots, h, dh)
