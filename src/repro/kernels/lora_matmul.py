"""Pallas TPU kernel: fused GeoLoRA linear  y = x @ W + s * (x @ A) @ B.

Unfused, the LoRA path costs two extra HBM round-trips (materialising x@A
and its product).  Fused, each (bm, bn) output tile loads its x panel once,
computes the rank-r bottleneck in-register (r <= 64 << VMEM) and adds both
contributions before a single store.  K (d_in) is tiled with a VMEM f32
accumulator scratch; A's K-panel rides along the same K loop, so the fused
epilogue adds only the tiny (bm, r) @ (r, bn) MXU call on the last step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _lora_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
                 scale: float, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...],
                           preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        delta = jnp.dot(xa_ref[...].astype(b_ref.dtype), b_ref[...],
                        preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * delta).astype(o_ref.dtype)


def lora_matmul_pallas(x: Array, w: Array, a: Array, b: Array, *,
                       scale: float = 1.0, bm: int = 128, bn: int = 128,
                       bk: int = 512, interpret: bool = False) -> Array:
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) -> (M, N)."""
    m, k = x.shape
    _, n = w.shape
    r = a.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pk:
        a = jnp.pad(a, ((0, pk), (0, 0)))
    if pn:
        b = jnp.pad(b, ((0, 0), (0, pn)))
    mm, nn, kk = m + pm, n + pn, k + pk
    nk = kk // bk
    out = pl.pallas_call(
        functools.partial(_lora_kernel, scale=scale, nk=nk),
        grid=(mm // bm, nn // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((bk, r), lambda i, j, ki: (ki, 0)),
            pl.BlockSpec((r, bn), lambda i, j, ki: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b)
    return out[:m, :n]
