"""Pallas TPU kernel: chunked diagonal selective scan (Mamba-1 / RG-LRU).

The GPU Mamba kernel is a fused sequential sweep relying on shared-memory
warp shuffles — no TPU analogue.  The TPU adaptation (see DESIGN.md)
re-blocks the recurrence: grid (B, C/bc, S/chunk) with the channel-blocked
state carried in VMEM scratch across sequential chunk steps; inside a chunk
a ``fori_loop`` walks rows in VMEM (VPU elementwise work; there is no MXU
contraction in a diagonal scan, so the kernel is memory-bound by design —
the roofline's memory term).

Channels C = d_inner * state for Mamba (flattened) or lru_width for RG-LRU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _scan_kernel(da_ref, dbx_ref, h0_ref, h_ref, hlast_ref, carry_ref, *,
                 chunk: int, nchunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)      # (1, bc) -> (bc,)

    da = da_ref[0].astype(jnp.float32)                      # (chunk, bc)
    dbx = dbx_ref[0].astype(jnp.float32)

    def step(t, h):
        h = da[t] * h + dbx[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, carry_ref[...])
    carry_ref[...] = h

    @pl.when(ci == nchunks - 1)
    def _done():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def selective_scan_pallas(da: Array, dbx: Array, h0: Array, *,
                          chunk: int = 128, bc: int = 512,
                          interpret: bool = False):
    """da, dbx: (B, S, C); h0: (B, C) -> (h_all (B, S, C), h_last (B, C))."""
    b, s, c = da.shape
    chunk = min(chunk, s)
    bc = min(bc, c)
    ps, pc = (-s) % chunk, (-c) % bc
    if ps or pc:
        da = jnp.pad(da, ((0, 0), (0, ps), (0, pc)), constant_values=1.0)
        dbx = jnp.pad(dbx, ((0, 0), (0, ps), (0, pc)))
    if pc:
        h0 = jnp.pad(h0, ((0, 0), (0, pc)))
    ss, cc = s + ps, c + pc
    nchunks = ss // chunk
    h_all, h_last = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk, nchunks=nchunks),
        grid=(b, cc // bc, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, bc), lambda bi, cj, ci: (bi, ci, cj)),
            pl.BlockSpec((1, chunk, bc), lambda bi, cj, ci: (bi, ci, cj)),
            pl.BlockSpec((1, bc), lambda bi, cj, ci: (bi, cj)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bc), lambda bi, cj, ci: (bi, ci, cj)),
            pl.BlockSpec((1, bc), lambda bi, cj, ci: (bi, cj)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, ss, cc), jnp.float32),
            jax.ShapeDtypeStruct((b, cc), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        interpret=interpret,
    )(da, dbx, h0)
    return h_all[:, :s, :c], h_last[:, :c]
