"""Pallas TPU kernel: blockwise (flash) causal attention.

Grid (BH, nq, nkv) with the KV axis innermost so the online-softmax
running state (m, l, acc) lives in VMEM scratch across sequential KV steps.
Causal block skipping: KV blocks strictly above the diagonal are skipped
with ``pl.when`` — the FLOP savings the jnp oracle (masking only) cannot
express; roofline §Perf quantifies the difference.

Q/K/V tiles are (bq, dh)/(bkv, dh) VMEM panels; dh <= 256 for all assigned
archs, so a 512 x 256 f32 panel is 0.5 MB — four panels + scratch fit VMEM
with room for double buffering.  GQA is handled by folding heads into the
leading BH axis and mapping each Q head onto its KV group via the
BlockSpec index_map (no materialised K/V repeat in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, nkv: int, bq: int, bkv: int,
                  sk_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip KV blocks entirely above the diagonal
    run = (not causal) or (ki * bkv <= qi * bq + bq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale           # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                   # (bkv, dh)
        s = q @ k.T                                        # (bq, bkv)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        ok = kpos < sk_valid                               # mask KV padding
        if causal:
            ok = ok & (kpos <= qpos)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr \
            + p @ v_ref[0].astype(jnp.float32)

    @pl.when(ki == nkv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True, scale: float = None,
                           bq: int = 512, bkv: int = 512, n_rep: int = 1,
                           interpret: bool = False) -> Array:
    """q: (BH, Sq, dh); k, v: (BH//n_rep, Sk, dh). GQA: q head h reads KV
    head h // n_rep via the index_map (zero-copy grouping)."""
    bh, sq, dh = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else dh ** -0.5
    bq = min(bq, sq)
    bkv = min(bkv, sk)
    pq, pk_ = (-sq) % bq, (-sk) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk_:
        k = jnp.pad(k, ((0, 0), (0, pk_), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk_), (0, 0)))
    nq, nkv = (sq + pq) // bq, (sk + pk_) // bkv
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          nkv=nkv, bq=bq, bkv=bkv, sk_valid=sk),
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, i, j, n_rep=n_rep:
                         (b // n_rep, j, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, i, j, n_rep=n_rep:
                         (b // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
