"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
swept against in tests/test_kernels_*.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def cosine_gram_ref(x: Array, eps: float = 1e-8) -> Array:
    """(B, D) -> (B, B) pairwise cosine similarities (paper Eq. 1)."""
    x32 = x.astype(jnp.float32)
    n = jnp.sqrt(jnp.maximum((x32 * x32).sum(-1, keepdims=True), eps))
    xn = x32 / n
    return xn @ xn.T


def lora_matmul_ref(x: Array, w: Array, a: Array, b: Array,
                    scale: float = 1.0) -> Array:
    """y = x @ W + scale * (x @ A) @ B  (GeoLoRA fused linear).
    x: (M, K); w: (K, N); a: (K, r); b: (r, N)."""
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    y = y + scale * (x.astype(jnp.float32) @ a.astype(jnp.float32)
                     ) @ b.astype(jnp.float32)
    return y.astype(x.dtype)


def flash_attention_ref(q: Array, k: Array, v: Array, *,
                        causal: bool = True, scale: float = None) -> Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh) (GQA folded into BH upstream)."""
    sq, sk = q.shape[1], k.shape[1]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None] + (sk - sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q: Array, k: Array, v: Array, q_pos: Array,
                         kv_pos: Array, *, window: int = 0,
                         scale: float = None) -> Array:
    """Masked single-token decode attention over a packed KV pool.
    q: (S, H, dh); k, v: (S, C, KV, dh); q_pos: (S,); kv_pos: (S, C).
    Query head h reads KV head h // (H // KV).  window=0 means un-windowed
    (a linear buffer never holds positions older than C)."""
    s_slots, h, dh = q.shape
    c, n_kv = k.shape[1], k.shape[2]
    rep = h // n_kv
    window = window or c
    scale = scale if scale is not None else dh ** -0.5
    qg = q.astype(jnp.float32).reshape(s_slots, n_kv, rep, dh) * scale
    sc = jnp.einsum("bgrd,bcgd->bgrc", qg, k.astype(jnp.float32))
    qp = q_pos[:, None, None, None].astype(jnp.int32)
    kp = kv_pos[:, None, None, :].astype(jnp.int32)
    ok = (kp <= qp) & (qp - kp < window)
    sc = jnp.where(ok, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bgrc,bcgd->bgrd", p, v.astype(jnp.float32))
    return out.reshape(s_slots, h, dh).astype(q.dtype)


def selective_scan_ref(da: Array, dbx: Array, h0: Array) -> tuple:
    """Diagonal recurrence h_t = da_t * h_{t-1} + dbx_t.
    da, dbx: (B, S, C); h0: (B, C) -> (h_all (B, S, C), h_last (B, C))."""
    def step(h, xs):
        a, b = xs
        h = a * h + b
        return h, h
    da_t = jnp.moveaxis(da.astype(jnp.float32), 1, 0)
    dbx_t = jnp.moveaxis(dbx.astype(jnp.float32), 1, 0)
    h_last, h_all = jax.lax.scan(step, h0.astype(jnp.float32), (da_t, dbx_t))
    return jnp.moveaxis(h_all, 0, 1), h_last
