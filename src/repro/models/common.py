"""Shared building blocks: norms, RoPE, linear (+GeoLoRA/GeoDoRA hooks), MLP.

Parameters are plain pytrees (nested dicts).  Every linear is a dict
``{"w": (d_in, d_out)[, "lora_A": (d_in, r), "lora_B": (r, d_out),
"dora_m": (d_out,)]}`` so the paper's GeoLoRA / GeoDoRA attach uniformly to
any weight in any architecture.  ``lora_A`` is the federation-shared frozen
projection (paper Eq. 4); only ``lora_B`` (and ``dora_m``) are trainable and
communicated.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def truncated_normal_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def make_linear(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": truncated_normal_init(key, (d_in, d_out), scale, dtype)}


def add_lora(key, lin: dict, rank: int, dtype, a_std: float = 1.0) -> dict:
    """Attach GeoLoRA params. ``lora_A`` is Gaussian and FROZEN (shared across
    federation nodes, paper Eq. 4); ``lora_B`` starts at zero."""
    d_in, d_out = lin["w"].shape[-2:]
    batch_shape = lin["w"].shape[:-2]
    ka, _ = jax.random.split(key)
    lin = dict(lin)
    lin["lora_A"] = (a_std * rank ** -0.5 *
                     jax.random.normal(ka, batch_shape + (d_in, rank))).astype(dtype)
    lin["lora_B"] = jnp.zeros(batch_shape + (rank, d_out), dtype)
    return lin


def add_dora(lin: dict) -> dict:
    """Attach the GeoDoRA magnitude vector, initialised to column norms of W
    (so the initial decomposition is exact, per DoRA [arXiv:2402.09353])."""
    lin = dict(lin)
    w = lin["w"].astype(jnp.float32)
    lin["dora_m"] = jnp.sqrt((w * w).sum(axis=-2)).astype(lin["w"].dtype)
    return lin


def dora_column_norm(w: Array, a: Array, b: Array, eps: float = 1e-6) -> Array:
    """||W + A@B||_col without materialising A@B:
    ||col_j||^2 = ||W_j||^2 + 2 (W^T A B)_jj + (B^T (A^T A) B)_jj."""
    w32, a32, b32 = (t.astype(jnp.float32) for t in (w, a, b))
    wsq = (w32 * w32).sum(axis=-2)
    m = jnp.einsum("...ij,...ir->...jr", w32, a32)          # (d_out, r)
    cross = jnp.einsum("...jr,...rj->...j", m, b32)
    g = jnp.einsum("...ir,...is->...rs", a32, a32)           # (r, r)
    bsq = jnp.einsum("...rj,...rs,...sj->...j", b32, g, b32)
    return jnp.sqrt(jnp.maximum(wsq + 2.0 * cross + bsq, eps))


def linear(x: Array, lin: dict, lora_scale: float = 1.0) -> Array:
    """Apply a (possibly GeoLoRA/GeoDoRA-augmented) linear layer."""
    w = lin["w"]
    y = x @ w.astype(x.dtype)
    if "lora_A" in lin:
        a = jax.lax.stop_gradient(lin["lora_A"]).astype(x.dtype)  # frozen shared A
        b = lin["lora_B"].astype(x.dtype)
        delta = (x @ a) @ b
        y = y + lora_scale * delta
        if "dora_m" in lin:
            norm = dora_column_norm(jax.lax.stop_gradient(w), a,
                                    lora_scale * b).astype(x.dtype)
            y = y * (lin["dora_m"].astype(x.dtype) / norm)
    elif "dora_m" in lin:
        norm = dora_column_norm(jax.lax.stop_gradient(w),
                                jnp.zeros(w.shape[:-2] + (w.shape[-2], 1), w.dtype),
                                jnp.zeros(w.shape[:-2] + (1, w.shape[-1]), w.dtype))
        y = y * (lin["dora_m"].astype(x.dtype) / norm.astype(x.dtype))
    return y


# ----------------------------------------------------------------------
def rms_norm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def make_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, d_head); positions: (..., S) int32."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                        # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> Array:
    """Whisper-style sinusoidal embeddings."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d_model, 2, dtype=jnp.float32)
                  / d_model)
    emb = jnp.zeros((seq_len, d_model), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb


# ----------------------------------------------------------------------
def make_swiglu(key, d_model: int, d_ff: int, dtype) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": make_linear(kg, d_model, d_ff, dtype),
        "up": make_linear(ku, d_model, d_ff, dtype),
        "down": make_linear(kd, d_ff, d_model, dtype),
    }


def swiglu(params: dict, x: Array) -> Array:
    g = linear(x, params["gate"])
    u = linear(x, params["up"])
    return linear(jax.nn.silu(g) * u, params["down"])


def make_gelu_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    ku, kd = jax.random.split(key)
    return {"up": make_linear(ku, d_model, d_ff, dtype),
            "down": make_linear(kd, d_ff, d_model, dtype)}


def gelu_mlp(params: dict, x: Array) -> Array:
    return linear(jax.nn.gelu(linear(x, params["up"])), params["down"])


# ----------------------------------------------------------------------
def cross_entropy_loss(logits: Array, labels: Array,
                       mask: Optional[Array] = None) -> Array:
    """Mean next-token CE in f32. logits: (..., V); labels int32 (...,)."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def mean_pool(x: Array, mask: Optional[Array] = None) -> Array:
    """Paper's Pool(): mean over the token axis -> (..., d_model)."""
    if mask is None:
        return x.mean(axis=-2)
    m = mask[..., None].astype(x.dtype)
    return (x * m).sum(axis=-2) / jnp.maximum(m.sum(axis=-2), 1.0)
