"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Same TPU chunked-scan machinery as the Mamba block (diagonal linear
recurrence), with the Griffin gating:
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import linear, make_linear
from repro.models.ssm import _chunked_diag_scan, causal_conv1d

Array = jax.Array
_C = 8.0


def lru_width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def make_rglru_block(key, cfg: ModelConfig, dtype) -> dict:
    """Full Griffin recurrent block: two input branches + RG-LRU + output."""
    d, w = cfg.d_model, lru_width(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # Lambda init so a^c in [0.9, 0.999] (paper appendix)
    u = jax.random.uniform(k6, (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))          # softplus^-1
    return {
        "in_gate": make_linear(k1, d, w, dtype),         # gelu gate branch
        "in_rec": make_linear(k2, d, w, dtype),          # recurrent branch
        "conv_w": (0.1 * jax.random.normal(k3, (cfg.rglru.conv_kernel, w))
                   ).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": make_linear(k4, w, w, dtype),             # recurrence gate
        "w_x": make_linear(k5, w, w, dtype),             # input gate
        "lam": lam,                                      # f32
        "out": make_linear(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _rglru_core(p: dict, xr: Array, h0: Array, chunk: int
                ) -> Tuple[Array, Array]:
    """xr: (B,S,w) post-conv recurrent branch -> (h_all, h_last)."""
    r = jax.nn.sigmoid(linear(xr, p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(xr, p["w_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (B,S,w)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i * xr.astype(jnp.float32))
    return _chunked_diag_scan(a, gated, h0, chunk)


def rglru_forward(p: dict, x: Array, cfg: ModelConfig, *,
                  h0: Array = None, conv0: Array = None
                  ) -> Tuple[Array, dict]:
    """x: (B,S,D) -> (B,S,D); returns (y, state)."""
    b, s, _ = x.shape
    w = lru_width(cfg)
    gate = jax.nn.gelu(linear(x, p["in_gate"]))
    xr = linear(x, p["in_rec"])
    if conv0 is not None:
        cat = jnp.concatenate([conv0.astype(xr.dtype), xr], axis=1)
        xr_c = causal_conv1d(cat, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        xr_c = causal_conv1d(xr, p["conv_w"], p["conv_b"])
    h0 = h0 if h0 is not None else jnp.zeros((b, w), jnp.float32)
    h_all, h_last = _rglru_core(p, xr_c, h0, cfg.rglru.chunk)
    y = (h_all.astype(x.dtype) * gate)
    state = {"h": h_last, "conv": xr[:, -(cfg.rglru.conv_kernel - 1):, :]}
    return linear(y, p["out"]), state


def init_rglru_state(batch: int, cfg: ModelConfig, dtype) -> dict:
    w = lru_width(cfg)
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.rglru.conv_kernel - 1, w), dtype)}


def rglru_decode(p: dict, x: Array, state: dict, cfg: ModelConfig
                 ) -> Tuple[Array, dict]:
    """Single-token decode, O(1) state."""
    gate = jax.nn.gelu(linear(x, p["in_gate"]))          # (B,1,w)
    xr = linear(x, p["in_rec"])
    conv_buf = jnp.concatenate([state["conv"].astype(xr.dtype), xr], axis=1)
    cw = p["conv_w"].astype(jnp.float32)
    xr_c = (conv_buf.astype(jnp.float32) * cw[None]).sum(axis=1, keepdims=True) \
        + p["conv_b"].astype(jnp.float32)
    xr_c = xr_c.astype(x.dtype)
    r = jax.nn.sigmoid(linear(xr_c, p["w_a"]).astype(jnp.float32))[:, 0]
    i = jax.nn.sigmoid(linear(xr_c, p["w_x"]).astype(jnp.float32))[:, 0]
    a = jnp.exp(-_C * jax.nn.softplus(p["lam"]) * r)
    h = a * state["h"] + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i * xr_c[:, 0].astype(jnp.float32))
    y = (h.astype(x.dtype)[:, None] * gate)
    new_state = {"h": h, "conv": conv_buf[:, 1:]}
    return linear(y, p["out"]), new_state
