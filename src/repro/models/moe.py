"""Mixture-of-Experts FFN with TPU-idiomatic expert parallelism.

Experts are sharded over the mesh ``model`` axis.  Inside ``shard_map`` each
device processes only its local experts via capacity-based gather -> expert
FFN -> weighted scatter-add, then contributions are combined with a ``psum``
over the model axis (the expert-parallel collective that shows up in the
roofline).  Shared (always-on) experts are a plain tensor-parallel SwiGLU
computed outside the shard_map.  Without a mesh (CPU smoke tests) the same
capacity kernel runs over all experts locally.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple


def _capacity(t: int, top_k: int, n_experts: int, factor: float) -> int:
    """Per-expert token capacity. The standard formula, floored so tiny
    token counts (decode steps) never drop tokens."""
    cap = int(math.ceil(t * top_k / n_experts * factor))
    return min(t, max(cap, 8))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import linear, make_linear, make_swiglu, swiglu

Array = jax.Array


def make_moe(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    kr, ke, ks = jax.random.split(key, 3)
    keg, keu, ked = jax.random.split(ke, 3)
    e = m.num_experts
    p = {
        # router kept in f32 for routing stability (standard practice)
        "router": {"w": (d ** -0.5 * jax.random.normal(kr, (d, e))).astype(jnp.float32)},
        "experts": {
            "gate": {"w": (d ** -0.5 * jax.random.normal(keg, (e, d, f))).astype(dtype)},
            "up": {"w": (d ** -0.5 * jax.random.normal(keu, (e, d, f))).astype(dtype)},
            "down": {"w": (f ** -0.5 * jax.random.normal(ked, (e, f, d))).astype(dtype)},
        },
    }
    if m.num_shared_experts:
        p["shared"] = make_swiglu(ks, d, f * m.num_shared_experts, dtype)
    return p


def router_scores(p: dict, x: Array, cfg: ModelConfig
                  ) -> Tuple[Array, Array, dict]:
    """Full routing done once (replicated weights): returns dense per-expert
    combine scores (B, S, E) plus aux losses."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ p["router"]["w"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)          # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)             # renormalise
    # dense combine matrix: scores[t, e] = gate weight if e chosen else 0
    onehot = jax.nn.one_hot(gate_idx, m.num_experts, dtype=jnp.float32)
    scores = (gate_vals[..., None] * onehot).sum(axis=-2)        # (B,S,E)
    # aux losses (Switch-style load balance + router z-loss)
    frac_tokens = onehot.sum(axis=-2).mean(axis=(0, 1)) / m.top_k  # (E,)
    mean_prob = probs.mean(axis=(0, 1))
    aux = {
        "load_balance": m.num_experts * (frac_tokens * mean_prob).sum(),
        "router_z": (jax.nn.logsumexp(logits, axis=-1) ** 2).mean(),
    }
    return scores, gate_idx, aux


def _expert_block(weights: dict, x_flat: Array, scores: Array,
                  capacity: int) -> Array:
    """Process local experts on flat tokens. weights leaves: (E_loc, ...);
    scores: (T, E_loc). Returns (T, D) combined output."""
    t, d = x_flat.shape

    def one_expert(y, packed):
        wg, wu, wd, s_e = packed                                  # s_e: (T,)
        top_s, top_idx = jax.lax.top_k(s_e, capacity)             # (C,)
        xg = x_flat[top_idx]                                      # (C, D)
        h = jax.nn.silu(xg @ wg.astype(xg.dtype)) * (xg @ wu.astype(xg.dtype))
        yg = (h @ wd.astype(h.dtype)) * top_s[:, None].astype(x_flat.dtype)
        return y.at[top_idx].add(yg), None

    y0 = jnp.zeros((t, d), x_flat.dtype)
    y, _ = jax.lax.scan(one_expert, y0,
                        (weights["gate"]["w"], weights["up"]["w"],
                         weights["down"]["w"], scores.T))
    return y


def moe_ffn(p: dict, x: Array, cfg: ModelConfig, *,
            mesh=None, ep_axis: Optional[str] = None,
            batch_axes: Tuple[str, ...] = ()) -> Tuple[Array, dict]:
    """x: (B, S, D) -> (B, S, D), aux losses."""
    m = cfg.moe
    b, s, d = x.shape
    scores, _, aux = router_scores(p, x, cfg)

    if mesh is not None and ep_axis is not None and \
            mesh.shape[ep_axis] > 1:
        ep = mesh.shape[ep_axis]
        assert m.num_experts % ep == 0, \
            f"{m.num_experts} experts not divisible by {ep}-way {ep_axis}"
        batch_in_mesh = tuple(a for a in batch_axes if a in mesh.shape)
        n_data = math.prod(mesh.shape[a] for a in batch_in_mesh) or 1
        b_loc = b // n_data if b % n_data == 0 else b
        t_loc = b_loc * s
        capacity = _capacity(t_loc, m.top_k, m.num_experts, m.capacity_factor)
        bspec = batch_in_mesh if (b % n_data == 0 and n_data > 1) else None

        def routed(x_blk, sc_blk, wg, wu, wd):
            bb = x_blk.shape[0]
            xf = x_blk.reshape(bb * s, d)
            sf = sc_blk.reshape(bb * s, -1).astype(jnp.float32)
            y = _expert_block({"gate": {"w": wg}, "up": {"w": wu},
                               "down": {"w": wd}}, xf, sf, capacity)
            y = jax.lax.psum(y, ep_axis)
            return y.reshape(bb, s, d)

        y = jax.shard_map(
            routed, mesh=mesh,
            in_specs=(P(bspec, None, None), P(bspec, None, ep_axis),
                      P(ep_axis, None, None), P(ep_axis, None, None),
                      P(ep_axis, None, None)),
            out_specs=P(bspec, None, None),
            check_vma=False,
        )(x, scores, p["experts"]["gate"]["w"], p["experts"]["up"]["w"],
          p["experts"]["down"]["w"])
    else:
        t = b * s
        capacity = _capacity(t, m.top_k, m.num_experts, m.capacity_factor)
        y = _expert_block(p["experts"], x.reshape(t, d),
                          scores.reshape(t, -1).astype(jnp.float32),
                          capacity).reshape(b, s, d)

    if "shared" in p:
        y = y + swiglu(p["shared"], x)
    return y, aux
