"""Mamba-1 selective SSM block (Falcon-Mamba) with a TPU-adapted chunked
associative scan.

GPU Mamba uses a fused sequential CUDA kernel; on TPU we re-block the
recurrence: ``lax.scan`` over sequence chunks carrying the state, with a
log-depth ``associative_scan`` inside each chunk (HBM->VMEM friendly,
work-efficient).  The Pallas kernel in ``repro.kernels.selective_scan``
implements the same chunking with explicit VMEM tiles; this module is the
pure-jnp reference path used on CPU.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import linear, make_linear

Array = jax.Array


def dt_rank(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def make_mamba(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d, di, dtr, n = cfg.d_model, d_inner(cfg), dt_rank(cfg), s.state_dim
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # S4D-real initialisation of A
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    dt_init = jnp.exp(jax.random.uniform(k5, (di,), jnp.float32)
                      * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    return {
        "in_proj": make_linear(k1, d, 2 * di, dtype),
        "conv_w": (0.1 * jax.random.normal(k2, (s.conv_kernel, di))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": make_linear(k3, di, dtr + 2 * n, dtype),
        "dt_proj": make_linear(k4, dtr, di, dtype, scale=dtr ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(dt_init)).astype(jnp.float32),
        "a_log": jnp.log(a_init),                      # f32: A = -exp(a_log)
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": make_linear(k6, di, d, dtype),
    }


def causal_conv1d(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv. x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _chunked_diag_scan(da: Array, dbx: Array, h0: Array, chunk: int
                       ) -> Tuple[Array, Array]:
    """Diagonal linear recurrence h_t = da_t * h_{t-1} + dbx_t.
    da, dbx: (B, S, ...) f32; h0: (B, ...). Returns (h_all (B,S,...), h_last).
    lax.scan over S/chunk chunks, associative_scan inside each chunk."""
    b, s = da.shape[:2]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:  # identity elements: a=1, b=0 leave the state untouched
        cfg_pad = [(0, 0), (0, pad)] + [(0, 0)] * (da.ndim - 2)
        da = jnp.pad(da, cfg_pad, constant_values=1.0)
        dbx = jnp.pad(dbx, cfg_pad)
    n_chunks = (s + pad) // chunk
    tail = da.shape[2:]
    da_c = da.reshape((b, n_chunks, chunk) + tail).transpose(
        (1, 0, 2) + tuple(range(3, 3 + len(tail))))
    dbx_c = dbx.reshape((b, n_chunks, chunk) + tail).transpose(
        (1, 0, 2) + tuple(range(3, 3 + len(tail))))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    def body(h, blk):
        da_j, dbx_j = blk                               # (B, chunk, ...)
        a_cum, b_cum = jax.lax.associative_scan(combine, (da_j, dbx_j), axis=1)
        h_all = a_cum * h[:, None] + b_cum              # (B, chunk, ...)
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(body, h0, (da_c, dbx_c))
    h_all = h_chunks.transpose((1, 0, 2) + tuple(range(3, 3 + len(tail))))
    h_all = h_all.reshape((b, s + pad) + tail)[:, :s]
    return h_all, h_last


def mamba_forward(p: dict, x: Array, cfg: ModelConfig, *,
                  h0: Array = None, conv0: Array = None
                  ) -> Tuple[Array, dict]:
    """x: (B, S, D) -> (B, S, D). Returns (y, final_state)."""
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    di, n = d_inner(cfg), s_cfg.state_dim
    xz = linear(x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    if conv0 is not None:                               # stitch conv state
        x_cat = jnp.concatenate([conv0.astype(x_in.dtype), x_in], axis=1)
        x_conv = causal_conv1d(x_cat, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        x_conv = causal_conv1d(x_in, p["conv_w"], p["conv_b"])
    x_conv = jax.nn.silu(x_conv)

    dbl = linear(x_conv, p["x_proj"])
    dtr = dt_rank(cfg)
    dt_low, b_ssm, c_ssm = jnp.split(dbl, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(linear(dt_low, p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])                # (B,S,di)
    a = -jnp.exp(p["a_log"])                            # (di, N)
    da = jnp.exp(dt[..., None] * a)                     # (B,S,di,N)
    dbx = (dt * x_conv.astype(jnp.float32))[..., None] \
        * b_ssm.astype(jnp.float32)[..., None, :]       # (B,S,di,N)
    h0 = h0 if h0 is not None else jnp.zeros((b, di, n), jnp.float32)
    h_all, h_last = _chunked_diag_scan(da, dbx, h0, s_cfg.chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h_all,
                   c_ssm.astype(jnp.float32))           # (B,S,di)
    y = y + p["d_skip"] * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    state = {"h": h_last,
             "conv": x_in[:, -(s_cfg.conv_kernel - 1):, :]}
    return linear(y, p["out_proj"]), state


def init_mamba_state(batch: int, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    return {"h": jnp.zeros((batch, d_inner(cfg), s.state_dim), jnp.float32),
            "conv": jnp.zeros((batch, s.conv_kernel - 1, d_inner(cfg)), dtype)}


def mamba_decode(p: dict, x: Array, state: dict, cfg: ModelConfig
                 ) -> Tuple[Array, dict]:
    """Single-token decode. x: (B, 1, D); O(1) state update."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    n = s_cfg.state_dim
    xz = linear(x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)                 # (B,1,di)
    conv_buf = jnp.concatenate([state["conv"].astype(x_in.dtype), x_in], axis=1)
    w = p["conv_w"].astype(jnp.float32)                 # (K, di)
    x_conv = (conv_buf.astype(jnp.float32) * w[None]).sum(axis=1, keepdims=True) \
        + p["conv_b"].astype(jnp.float32)
    x_conv = jax.nn.silu(x_conv).astype(x.dtype)        # (B,1,di)

    dbl = linear(x_conv, p["x_proj"])
    dtr = dt_rank(cfg)
    dt_low, b_ssm, c_ssm = jnp.split(dbl, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(linear(dt_low, p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])[:, 0]          # (B,di)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a)                     # (B,di,N)
    dbx = (dt * x_conv[:, 0].astype(jnp.float32))[..., None] \
        * b_ssm[:, 0].astype(jnp.float32)[:, None, :]   # (B,di,N)
    h = da * state["h"] + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0].astype(jnp.float32))
    y = y + p["d_skip"] * x_conv[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)[:, None]
    new_state = {"h": h, "conv": conv_buf[:, 1:]}
    return linear(y, p["out_proj"]), new_state
