"""The homogeneous transformer: init/forward/prefill/decode for every
assigned architecture family (dense, moe, ssm, hybrid, vlm, audio).

Layers are stacked (leading axis L) and executed with ``lax.scan`` so the
HLO stays compact for 40-64 layer models; ``Runtime.remat`` wraps the scan
body in ``jax.checkpoint`` for training.  Every linear accepts GeoLoRA /
GeoDoRA side-cars (see ``repro.core.lora.attach_lora``), which is how the
paper's technique composes with any backbone.

``prefill`` is a real prefill: the forward scan also emits per-layer cache
entries (rope'd K/V, MLA latents, or recurrent states), which are packed
into the decode cache — windowed attention uses a ring buffer layout.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    linear,
    make_linear,
    make_rms_norm,
    make_swiglu,
    mean_pool,
    rms_norm,
    sinusoidal_positions,
    swiglu,
    truncated_normal_init,
)

Array = jax.Array
_SENTINEL = jnp.iinfo(jnp.int32).max // 2


@dataclass(frozen=True)
class Runtime:
    """Execution context threaded through model calls."""
    mesh: Any = None
    ep_axis: Optional[str] = None            # expert-parallel mesh axis
    batch_axes: Tuple[str, ...] = ()
    remat: bool = False
    window_override: int = 0                 # force SWA width (long_500k variant)
    use_pallas: bool = False
    seq_shard: bool = False                  # sequence-parallel residual stream
    kv_block: int = 0                        # attention KV block override
    sp_attn_gather: bool = False             # Megatron-SP gather at attention


def _seq_constraint(x, rt: "Runtime"):
    """Megatron-style sequence parallelism: between layers the residual
    stream (B, S, D) is sharded over (batch axes, 'model', None), so saved
    remat residuals scale with 1/model_parallel.  XLA inserts the
    all-gather before attention/FFN and the reduce-scatter after."""
    if not rt.seq_shard or rt.mesh is None:
        return x
    from jax.sharding import PartitionSpec as P
    if x.ndim != 3 or x.shape[1] % rt.mesh.shape.get("model", 1):
        return x
    bspec = rt.batch_axes if (rt.batch_axes and
                              x.shape[0] % _axes_size(rt) == 0) else None
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rt.mesh, P(bspec, "model", None)))


def _axes_size(rt: "Runtime") -> int:
    n = 1
    for a in rt.batch_axes:
        n *= rt.mesh.shape.get(a, 1)
    return n


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _attn_kind(cfg: ModelConfig, rt: Runtime) -> Tuple[str, int]:
    if rt.window_override:
        return "sliding", rt.window_override
    if cfg.sliding_window:
        return "sliding", cfg.sliding_window
    if cfg.attention_chunk:
        return "chunked", cfg.attention_chunk
    return "causal", 0


# ======================================================================
# init
def _stack_init(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def _make_dense_block(cfg: ModelConfig, dtype):
    def f(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": make_rms_norm(cfg.d_model, dtype),
            "attn": attn.make_gqa(k1, cfg, dtype),
            "ln2": make_rms_norm(cfg.d_model, dtype),
            "mlp": make_swiglu(k2, cfg.d_model, cfg.d_ff, dtype),
        }
    return f


def _make_moe_block(cfg: ModelConfig, dtype):
    def f(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": make_rms_norm(cfg.d_model, dtype),
            "attn": (attn.make_mla(k1, cfg, dtype) if cfg.mla is not None
                     else attn.make_gqa(k1, cfg, dtype)),
            "ln2": make_rms_norm(cfg.d_model, dtype),
            "moe": moe_mod.make_moe(k2, cfg, dtype),
        }
    return f


def _make_ssm_block(cfg: ModelConfig, dtype):
    def f(k):
        return {"ln": make_rms_norm(cfg.d_model, dtype),
                "mixer": ssm_mod.make_mamba(k, cfg, dtype)}
    return f


def _make_hybrid_rec_block(cfg: ModelConfig, dtype):
    def f(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": make_rms_norm(cfg.d_model, dtype),
            "mixer": rglru_mod.make_rglru_block(k1, cfg, dtype),
            "ln2": make_rms_norm(cfg.d_model, dtype),
            "mlp": make_swiglu(k2, cfg.d_model, cfg.d_ff, dtype),
        }
    return f


def _make_dec_block(cfg: ModelConfig, dtype):
    def f(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": make_rms_norm(cfg.d_model, dtype),
            "self_attn": attn.make_gqa(k1, cfg, dtype),
            "ln2": make_rms_norm(cfg.d_model, dtype),
            "cross_attn": attn.make_gqa(k2, cfg, dtype),
            "ln3": make_rms_norm(cfg.d_model, dtype),
            "mlp": make_swiglu(k3, cfg.d_model, cfg.d_ff, dtype),
        }
    return f


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg)
    ke, kb, kh, kx = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "embed": truncated_normal_init(ke, (cfg.vocab_size, cfg.d_model),
                                       dtype=dtype),
        "final_norm": make_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = make_linear(kh, cfg.d_model, cfg.vocab_size, dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["blocks"] = _stack_init(kb, cfg.n_layers, _make_dense_block(cfg, dtype))
        if fam == "vlm":
            p["adapter"] = make_linear(kx, cfg.image_embed_dim, cfg.d_model, dtype)
    elif fam == "moe":
        p["blocks"] = _stack_init(kb, cfg.n_layers, _make_moe_block(cfg, dtype))
    elif fam == "ssm":
        p["blocks"] = _stack_init(kb, cfg.n_layers, _make_ssm_block(cfg, dtype))
    elif fam == "hybrid":
        pat = cfg.rglru.block_pattern
        n_groups, tail_n = divmod(cfg.n_layers, len(pat))
        kg, kt = jax.random.split(kb)

        def group_init(k):
            ks = jax.random.split(k, len(pat))
            return {f"b{i}": (_make_hybrid_rec_block(cfg, dtype)(ks[i])
                              if pat[i] == "recurrent"
                              else _make_dense_block(cfg, dtype)(ks[i]))
                    for i in range(len(pat))}
        p["groups"] = _stack_init(kg, n_groups, group_init)
        kts = jax.random.split(kt, max(tail_n, 1))
        p["tail"] = [
            (_make_hybrid_rec_block(cfg, dtype)(kts[i])
             if pat[i % len(pat)] == "recurrent"
             else _make_dense_block(cfg, dtype)(kts[i]))
            for i in range(tail_n)]
    elif fam == "audio":
        kenc, kdec = jax.random.split(kb)
        p["enc_blocks"] = _stack_init(kenc, cfg.n_encoder_layers,
                                      _make_dense_block(cfg, dtype))
        p["blocks"] = _stack_init(kdec, cfg.n_layers, _make_dec_block(cfg, dtype))
        p["enc_adapter"] = make_linear(kx, cfg.encoder_embed_dim, cfg.d_model,
                                       dtype)
        p["enc_norm"] = make_rms_norm(cfg.d_model, dtype)
    else:
        raise ValueError(fam)
    return p


# ======================================================================
# ring-buffer packing for windowed caches
def _ring_pack(x: Array, s: int, w: int, fill=0):
    """Pack the last min(s, w) entries of x (B, S, ...) into ring layout of
    width w where entry for position p sits at slot p % w."""
    if s >= w:
        last = x[:, s - w:]
        return jnp.roll(last, s % w, axis=1)
    pad_cfg = [(0, 0), (0, w - s)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad_cfg, constant_values=fill)


# ======================================================================
# block bodies. Each returns (x, aux) with aux = {"lb", "rz", "cache"}.
def _zero_aux(cache=None):
    return {"lb": jnp.zeros((), jnp.float32),
            "rz": jnp.zeros((), jnp.float32),
            "cache": cache}


def _attn_gather(x, rt):
    """Megatron-SP attention entry: force the block input to full sequence
    (replicated over 'model') so attention runs purely head-sharded; the
    exit _seq_constraint turns the output psum into a reduce-scatter.
    Without this, t-sharded queries force per-KV-block dK/dV all-reduces in
    the backward (measured: §Perf iter 5)."""
    if not rt.seq_shard or not rt.sp_attn_gather or rt.mesh is None \
            or x.ndim != 3:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    bspec = rt.batch_axes if (rt.batch_axes and
                              x.shape[0] % _axes_size(rt) == 0) else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rt.mesh, P(bspec, None, None)))


def _dense_body(cfg, rt, kind, window, collect: bool):
    def body(x, bp, positions):
        h = _attn_gather(rms_norm(x, bp["ln1"]["scale"], cfg.norm_eps), rt)
        if cfg.mla is not None:
            r = attn.mla_forward(bp["attn"], h, cfg, positions=positions,
                                 return_kv=collect, rt=rt)
        else:
            r = attn.gqa_forward(bp["attn"], h, cfg, kind=kind, window=window,
                                 positions=positions, return_kv=collect,
                                 rt=rt)
        h, kv = r if collect else (r, None)
        x = x + h
        h = rms_norm(x, bp["ln2"]["scale"], cfg.norm_eps)
        if "moe" in bp:
            h, moe_aux = moe_mod.moe_ffn(bp["moe"], h, cfg, mesh=rt.mesh,
                                         ep_axis=rt.ep_axis,
                                         batch_axes=rt.batch_axes)
            aux = _zero_aux(kv)
            aux["lb"] = moe_aux["load_balance"]
            aux["rz"] = moe_aux["router_z"]
        else:
            h, aux = swiglu(bp["mlp"], h), _zero_aux(kv)
        return x + h, aux
    return body


def _ssm_body(cfg, collect: bool):
    def body(x, bp, positions):
        h = rms_norm(x, bp["ln"]["scale"], cfg.norm_eps)
        y, state = ssm_mod.mamba_forward(bp["mixer"], h, cfg)
        return x + y, _zero_aux(state if collect else None)
    return body


def _hybrid_rec_body(cfg, collect: bool):
    def body(x, bp, positions):
        h = rms_norm(x, bp["ln1"]["scale"], cfg.norm_eps)
        y, state = rglru_mod.rglru_forward(bp["mixer"], h, cfg)
        x = x + y
        h = rms_norm(x, bp["ln2"]["scale"], cfg.norm_eps)
        return x + swiglu(bp["mlp"], h), _zero_aux(state if collect else None)
    return body


def _hybrid_attn_body(cfg, collect: bool, rt=None):
    w = cfg.rglru.local_window

    def body(x, bp, positions):
        h = rms_norm(x, bp["ln1"]["scale"], cfg.norm_eps)
        r = attn.gqa_forward(bp["attn"], h, cfg, kind="sliding", window=w,
                             positions=positions, return_kv=collect, rt=rt)
        h, kv = r if collect else (r, None)
        x = x + h
        h = rms_norm(x, bp["ln2"]["scale"], cfg.norm_eps)
        return x + swiglu(bp["mlp"], h), _zero_aux(kv)
    return body


# ======================================================================
def _run_stack(blocks, body, x, positions, remat: bool,
               rt: "Runtime" = None):
    def scan_body(carry, bp):
        y, aux = body(carry, bp, positions)
        if rt is not None:
            y = _seq_constraint(y, rt)
        return y, aux
    if remat:
        scan_body = jax.checkpoint(scan_body)
    x, aux = jax.lax.scan(scan_body, x, blocks)
    return x, aux


def _embed_inputs(params, batch, cfg: ModelConfig):
    if "inputs_embeds" in batch:                  # paper's adapter path
        x = batch["inputs_embeds"].astype(_dtype(cfg))
    else:
        x = params["embed"][batch["tokens"]]
    if cfg.family == "vlm" and "image_embeds" in batch:
        img = linear(batch["image_embeds"].astype(x.dtype), params["adapter"])
        x = jnp.concatenate([img, x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
    return x, positions


def _encoder_forward(params, batch, cfg: ModelConfig, rt: Runtime) -> Array:
    x = linear(batch["enc_embeds"].astype(_dtype(cfg)), params["enc_adapter"])
    s = x.shape[1]
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(x.shape[0], 0)

    def body(h, bp, pos):
        a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
        a = attn.gqa_forward(bp["attn"], a, cfg, kind="full", positions=pos,
                             rope=False)
        h = h + a
        m = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
        return h + swiglu(bp["mlp"], m), _zero_aux()
    x, _ = _run_stack(params["enc_blocks"], body, x, positions, rt.remat,
                      rt)
    return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def _dec_body(cfg, enc_out, collect: bool):
    def body(h, bp, pos):
        a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
        r = attn.gqa_forward(bp["self_attn"], a, cfg, kind="causal",
                             positions=pos, rope=False, return_kv=collect)
        a, kv = r if collect else (r, None)
        h = h + a
        c = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
        c = attn.gqa_forward(bp["cross_attn"], c, cfg, x_cross=enc_out,
                             positions=pos)
        h = h + c
        m = rms_norm(h, bp["ln3"]["scale"], cfg.norm_eps)
        cache = None
        if collect:
            cross = attn.precompute_cross_kv(bp["cross_attn"], enc_out, cfg)
            cache = {"k": kv["k"], "v": kv["v"],
                     "cross_k": cross["k"], "cross_v": cross["v"]}
        return h + swiglu(bp["mlp"], m), _zero_aux(cache)
    return body


def _forward_impl(params: dict, batch: dict, cfg: ModelConfig, rt: Runtime,
                  collect: bool):
    fam = cfg.family
    kind, window = _attn_kind(cfg, rt)
    tails_aux = []

    if fam == "audio":
        enc_out = _encoder_forward(params, batch, cfg, rt)
        x = params["embed"][batch["tokens"]]
        s = x.shape[1]
        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
        positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(x.shape[0], 0)
        x, aux = _run_stack(params["blocks"], _dec_body(cfg, enc_out, collect),
                            x, positions, rt.remat, rt)
    elif fam == "hybrid":
        x, positions = _embed_inputs(params, batch, cfg)
        pat = cfg.rglru.block_pattern
        rec_body = _hybrid_rec_body(cfg, collect)
        att_body = _hybrid_attn_body(cfg, collect, rt)

        def group_body(h, gp, pos):
            caches = {}
            lb = jnp.zeros((), jnp.float32)
            for i, kind_i in enumerate(pat):
                body_i = rec_body if kind_i == "recurrent" else att_body
                h, a = body_i(h, gp[f"b{i}"], pos)
                caches[f"b{i}"] = a["cache"]
            out_aux = _zero_aux(caches if collect else None)
            return h, out_aux
        x, aux = _run_stack(params["groups"], group_body, x, positions,
                            rt.remat, rt)
        for i, bp in enumerate(params["tail"]):
            body_i = rec_body if pat[i % len(pat)] == "recurrent" else att_body
            x, a = body_i(x, bp, positions)
            tails_aux.append(a)
    else:
        x, positions = _embed_inputs(params, batch, cfg)
        body = (_ssm_body(cfg, collect) if fam == "ssm"
                else _dense_body(cfg, rt, kind, window, collect))
        x, aux = _run_stack(params["blocks"], body, x, positions, rt.remat,
                            rt)

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    out_aux = {
        "load_balance": aux["lb"].mean(),
        "router_z": aux["rz"].mean(),
        "pooled": mean_pool(x),
        "_cache": aux["cache"],
        "_tail_caches": [a["cache"] for a in tails_aux],
    }
    if cfg.family == "vlm" and "image_embeds" in batch:
        x = x[:, batch["image_embeds"].shape[1]:]
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = linear(x, params["lm_head"])
    return logits, out_aux


def forward(params: dict, batch: dict, cfg: ModelConfig,
            rt: Runtime = Runtime()) -> Tuple[Array, dict]:
    """Full-sequence forward -> (logits, aux). aux['pooled'] (B, d_model)
    feeds the paper's Gram/CKA alignment."""
    logits, aux = _forward_impl(params, batch, cfg, rt, collect=False)
    aux.pop("_cache"), aux.pop("_tail_caches")
    return logits, aux


# ======================================================================
# prefill: forward + pack the collected per-layer caches for decode
def prefill(params: dict, batch: dict, cfg: ModelConfig,
            rt: Runtime = Runtime(), cache_len: Optional[int] = None
            ) -> Tuple[Array, dict]:
    """Prefill: forward + pack per-layer caches, with room to decode up to
    ``cache_len`` total positions (defaults to S + 1024)."""
    logits, aux = _forward_impl(params, batch, cfg, rt, collect=True)
    raw, tails = aux.pop("_cache"), aux.pop("_tail_caches")
    fam = cfg.family
    kind, window = _attn_kind(cfg, rt)

    def grow(x, target, axis, fill=0):
        if x.shape[axis] >= target:
            return x
        cfg_pad = [(0, 0)] * x.ndim
        cfg_pad[axis] = (0, target - x.shape[axis])
        return jnp.pad(x, cfg_pad, constant_values=fill)

    def pack_kv(kv, w, target):
        """kv leaves (L, B, S, ...) -> ring/full cache + pos."""
        s = kv["k"].shape[2]
        b = kv["k"].shape[1]
        pos_vals = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
        if w:
            pk = jax.vmap(lambda t: _ring_pack(t, s, w))(kv["k"])
            pv = jax.vmap(lambda t: _ring_pack(t, s, w))(kv["v"])
            pp1 = _ring_pack(pos_vals, s, w, fill=_SENTINEL)      # (B, w)
        else:
            pk = grow(kv["k"], target, 2)
            pv = grow(kv["v"], target, 2)
            pp1 = grow(pos_vals, target, 1, fill=_SENTINEL)
        pp = jnp.broadcast_to(pp1, (kv["k"].shape[0],) + pp1.shape)
        return {"k": pk, "v": pv, "pos": pp}

    s_len = logits.shape[1]
    if fam == "vlm" and "image_embeds" in batch:
        s_len = s_len + batch["image_embeds"].shape[1]
    target = cache_len if cache_len is not None else s_len + 1024
    if fam in ("dense", "vlm", "moe") and cfg.mla is None:
        cache = pack_kv(raw, window, target)
    elif fam == "moe":                          # MLA
        cache = {"c_kv": grow(raw["c_kv"], target, 2),
                 "k_rope": grow(raw["k_rope"], target, 2)}
    elif fam == "ssm":
        cache = raw                              # stacked states (L, B, ...)
    elif fam == "hybrid":
        pat = cfg.rglru.block_pattern
        w = cfg.rglru.local_window
        groups = {}
        for i, kind_i in enumerate(pat):
            groups[f"b{i}"] = (raw[f"b{i}"] if kind_i == "recurrent"
                               else pack_kv(raw[f"b{i}"], w, target))
        tail = []
        for i, tc in enumerate(tails):
            if pat[i % len(pat)] == "recurrent":
                tail.append(tc)
            else:
                one = {k: v[None] for k, v in tc.items()}
                packed = pack_kv(one, w, target)
                tail.append({k: v[0] for k, v in packed.items()})
        cache = {"groups": groups, "tail": tail}
    elif fam == "audio":
        cache = pack_kv({"k": raw["k"], "v": raw["v"]}, 0, target)
        cache["cross_k"] = raw["cross_k"]
        cache["cross_v"] = raw["cross_v"]
    else:
        raise ValueError(fam)
    cache["len"] = jnp.asarray(s_len, jnp.int32)
    return logits, cache


# ======================================================================
# decode
def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               rt: Runtime = Runtime()) -> dict:
    dtype = _dtype(cfg)
    fam = cfg.family
    L = cfg.n_layers
    kind, window = _attn_kind(cfg, rt)
    eff_len = min(cache_len, window) if window else cache_len

    def kv(n, b, length, n_kv):
        shape = (n, b, length, n_kv, cfg.head_dim) if n else \
            (b, length, n_kv, cfg.head_dim)
        pshape = (n, b, length) if n else (b, length)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": jnp.full(pshape, _SENTINEL, jnp.int32)}

    if fam in ("dense", "vlm") or (fam == "moe" and cfg.mla is None):
        c = kv(L, batch, eff_len, cfg.n_kv_heads)
    elif fam == "moe":
        m = cfg.mla
        c = {"c_kv": jnp.zeros((L, batch, eff_len, m.kv_lora_rank), dtype),
             "k_rope": jnp.zeros((L, batch, eff_len, m.rope_head_dim), dtype)}
    elif fam == "ssm":
        st = ssm_mod.init_mamba_state(batch, cfg, dtype)
        c = {k: jnp.broadcast_to(v, (L,) + v.shape).copy()
             for k, v in st.items()}
    elif fam == "hybrid":
        pat = cfg.rglru.block_pattern
        n_groups, tail_n = divmod(cfg.n_layers, len(pat))
        w = rglru_mod.lru_width(cfg)
        alen = min(cache_len, cfg.rglru.local_window)

        def rec_state(n):
            shape_h = (n, batch, w) if n else (batch, w)
            shape_c = ((n, batch, cfg.rglru.conv_kernel - 1, w) if n
                       else (batch, cfg.rglru.conv_kernel - 1, w))
            return {"h": jnp.zeros(shape_h, jnp.float32),
                    "conv": jnp.zeros(shape_c, dtype)}
        groups = {f"b{i}": (rec_state(n_groups) if pat[i] == "recurrent"
                            else kv(n_groups, batch, alen, cfg.n_kv_heads))
                  for i in range(len(pat))}
        tail = [(rec_state(0) if pat[i % len(pat)] == "recurrent"
                 else kv(0, batch, alen, cfg.n_kv_heads))
                for i in range(tail_n)]
        c = {"groups": groups, "tail": tail}
    elif fam == "audio":
        c = kv(L, batch, eff_len, cfg.n_kv_heads)
        c["cross_k"] = jnp.zeros((L, batch, cfg.encoder_seq_len,
                                  cfg.n_kv_heads, cfg.head_dim), dtype)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
    else:
        raise ValueError(fam)
    c["len"] = jnp.zeros((), jnp.int32)
    return c


def decode_step(params: dict, cache: dict, batch: dict, cfg: ModelConfig,
                rt: Runtime = Runtime()) -> Tuple[Array, dict]:
    """One new token for every sequence. batch: {'tokens': (B, 1)}."""
    fam = cfg.family
    kind, window = _attn_kind(cfg, rt)
    x = params["embed"][batch["tokens"]]
    pos = cache["len"]

    if fam == "audio":
        x = x + jax.lax.dynamic_slice_in_dim(
            sinusoidal_positions(65536, cfg.d_model), pos, 1,
            axis=0).astype(x.dtype)[None]

        def body(h, layer):
            bp, kc, vc, pc, ck, cv = layer
            a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
            lc = {"k": kc, "v": vc, "pos": pc, "len": pos}
            a, nc = attn.gqa_decode(bp["self_attn"], a, lc, cfg, kind="causal")
            h = h + a
            c = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
            c = attn.gqa_cross_decode(bp["cross_attn"], c,
                                      {"k": ck, "v": cv}, cfg)
            h = h + c
            m = rms_norm(h, bp["ln3"]["scale"], cfg.norm_eps)
            h = h + swiglu(bp["mlp"], m)
            return h, (nc["k"], nc["v"], nc["pos"])
        x, (nk, nv, np_) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"], cache["pos"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, k=nk, v=nv, pos=np_, len=pos + 1)
    elif fam == "ssm":
        def body(h, layer):
            bp, hs, cs = layer
            a = rms_norm(h, bp["ln"]["scale"], cfg.norm_eps)
            y, ns = ssm_mod.mamba_decode(bp["mixer"], a, {"h": hs, "conv": cs},
                                         cfg)
            return h + y, (ns["h"], ns["conv"])
        x, (nh, nc) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["h"], cache["conv"]))
        new_cache = dict(cache, h=nh, conv=nc, len=pos + 1)
    elif fam == "hybrid":
        pat = cfg.rglru.block_pattern
        w = cfg.rglru.local_window

        def rec_step(h, bp, st):
            a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
            y, ns = rglru_mod.rglru_decode(bp["mixer"], a, st, cfg)
            h = h + y
            m = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
            return h + swiglu(bp["mlp"], m), ns

        def att_step(h, bp, st):
            a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
            lc = dict(st, len=pos)
            a, nc = attn.gqa_decode(bp["attn"], a, lc, cfg, kind="sliding",
                                    window=w)
            h = h + a
            m = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
            nc.pop("len")
            return h + swiglu(bp["mlp"], m), nc

        def group_body(h, layer):
            gp, gc = layer
            ncs = {}
            for i, kind_i in enumerate(pat):
                step = rec_step if kind_i == "recurrent" else att_step
                h, ncs[f"b{i}"] = step(h, gp[f"b{i}"], gc[f"b{i}"])
            return h, ncs
        x, new_groups = jax.lax.scan(group_body, x,
                                     (params["groups"], cache["groups"]))
        new_tail = []
        for i, bp in enumerate(params["tail"]):
            step = rec_step if pat[i % len(pat)] == "recurrent" else att_step
            x, nc = step(x, bp, cache["tail"][i])
            new_tail.append(nc)
        new_cache = dict(cache, groups=new_groups, tail=new_tail, len=pos + 1)
    else:  # dense / vlm / moe
        is_mla = cfg.mla is not None

        def body(h, layer):
            if is_mla:
                bp, ck, kr = layer
                lc = {"c_kv": ck, "k_rope": kr, "len": pos}
            else:
                bp, kc, vc, pc = layer
                lc = {"k": kc, "v": vc, "pos": pc, "len": pos}
            a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
            if is_mla:
                a, nc = attn.mla_decode(bp["attn"], a, lc, cfg, rt=rt)
                out_c = (nc["c_kv"], nc["k_rope"])
            else:
                a, nc = attn.gqa_decode(bp["attn"], a, lc, cfg, kind=kind,
                                        window=window, rt=rt)
                out_c = (nc["k"], nc["v"], nc["pos"])
            h = h + a
            m = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
            if "moe" in bp:
                y, _ = moe_mod.moe_ffn(bp["moe"], m, cfg, mesh=rt.mesh,
                                       ep_axis=rt.ep_axis,
                                       batch_axes=rt.batch_axes)
            else:
                y = swiglu(bp["mlp"], m)
            return h + y, out_c

        if is_mla:
            xs = (params["blocks"], cache["c_kv"], cache["k_rope"])
            x, (nck, nkr) = jax.lax.scan(body, x, xs)
            new_cache = dict(cache, c_kv=nck, k_rope=nkr, len=pos + 1)
        else:
            xs = (params["blocks"], cache["k"], cache["v"], cache["pos"])
            x, (nk, nv, np_) = jax.lax.scan(body, x, xs)
            new_cache = dict(cache, k=nk, v=nv, pos=np_, len=pos + 1)

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = linear(x, params["lm_head"])
    return logits, new_cache


def decode_step_slots(params: dict, cache: dict, batch: dict,
                      cfg: ModelConfig, rt: Runtime = Runtime(), *,
                      step_mask: Optional[Array] = None,
                      attn_backend: str = "reference",
                      attn_interpret: bool = False) -> Tuple[Array, dict]:
    """One new token per SLOT, each slot at its own position (the serving
    cache pool's decode path).

    Unlike ``decode_step`` (one scalar ``cache['len']`` for the whole
    batch), ``cache['len']`` is (S,) int32 — slot s reads/writes its
    caches at position ``len[s]``, so freshly-admitted prompts and
    long-running decodes share one batched call without recompiling.
    ``step_mask`` (S,) bool freezes masked slots IN PLACE: their cache
    position does not advance, and recurrent state (SSM ``h``/``conv``,
    RG-LRU) is held — attention writes at a frozen position are
    idempotent, but a recurrent update is not, and the serving engine
    unmasks slots that later resume (deadline-cancelled or chaos-frozen
    slots), which must continue bit-identically.
    ``attn_backend='pallas'`` routes GQA slot attention to
    ``kernels.decode_attention`` (interpret mode off-TPU).
    """
    fam = cfg.family
    kind, window = _attn_kind(cfg, rt)
    x = params["embed"][batch["tokens"]]
    lens = cache["len"]                                  # (S,) int32
    akw = dict(backend=attn_backend, interpret=attn_interpret)

    def keep(new, old):
        """Hold recurrent state for masked slots (slot axis 0)."""
        if step_mask is None:
            return new
        m = step_mask.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)

    if fam == "audio":
        x = x + sinusoidal_positions(65536, cfg.d_model)[lens][:, None] \
            .astype(x.dtype)

        def body(h, layer):
            bp, kc, vc, pc, ck, cv = layer
            a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
            lc = {"k": kc, "v": vc, "pos": pc, "lens": lens}
            a, nc = attn.gqa_decode_slots(bp["self_attn"], a, lc, cfg,
                                          kind="causal", **akw)
            h = h + a
            c = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
            c = attn.gqa_cross_decode(bp["cross_attn"], c,
                                      {"k": ck, "v": cv}, cfg)
            h = h + c
            m = rms_norm(h, bp["ln3"]["scale"], cfg.norm_eps)
            h = h + swiglu(bp["mlp"], m)
            return h, (nc["k"], nc["v"], nc["pos"])
        x, (nk, nv, np_) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"], cache["pos"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, k=nk, v=nv, pos=np_)
    elif fam == "ssm":
        def body(h, layer):
            bp, hs, cs = layer
            a = rms_norm(h, bp["ln"]["scale"], cfg.norm_eps)
            y, ns = ssm_mod.mamba_decode(bp["mixer"], a, {"h": hs, "conv": cs},
                                         cfg)
            return h + y, (keep(ns["h"], hs), keep(ns["conv"], cs))
        x, (nh, nc) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["h"], cache["conv"]))
        new_cache = dict(cache, h=nh, conv=nc)
    elif fam == "hybrid":
        pat = cfg.rglru.block_pattern
        w = cfg.rglru.local_window

        def rec_step(h, bp, st):
            a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
            y, ns = rglru_mod.rglru_decode(bp["mixer"], a, st, cfg)
            h = h + y
            m = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
            return h + swiglu(bp["mlp"], m), jax.tree.map(keep, ns, st)

        def att_step(h, bp, st):
            a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
            lc = dict(st, lens=lens)
            a, nc = attn.gqa_decode_slots(bp["attn"], a, lc, cfg,
                                          kind="sliding", window=w, **akw)
            h = h + a
            m = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
            nc.pop("lens")
            return h + swiglu(bp["mlp"], m), nc

        def group_body(h, layer):
            gp, gc = layer
            ncs = {}
            for i, kind_i in enumerate(pat):
                step = rec_step if kind_i == "recurrent" else att_step
                h, ncs[f"b{i}"] = step(h, gp[f"b{i}"], gc[f"b{i}"])
            return h, ncs
        x, new_groups = jax.lax.scan(group_body, x,
                                     (params["groups"], cache["groups"]))
        new_tail = []
        for i, bp in enumerate(params["tail"]):
            step = rec_step if pat[i % len(pat)] == "recurrent" else att_step
            x, nc = step(x, bp, cache["tail"][i])
            new_tail.append(nc)
        new_cache = dict(cache, groups=new_groups, tail=new_tail)
    else:  # dense / vlm / moe
        is_mla = cfg.mla is not None

        def body(h, layer):
            if is_mla:
                bp, ck, kr = layer
                lc = {"c_kv": ck, "k_rope": kr, "lens": lens}
            else:
                bp, kc, vc, pc = layer
                lc = {"k": kc, "v": vc, "pos": pc, "lens": lens}
            a = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
            if is_mla:
                a, nc = attn.mla_decode_slots(bp["attn"], a, lc, cfg, rt=rt)
                out_c = (nc["c_kv"], nc["k_rope"])
            else:
                a, nc = attn.gqa_decode_slots(bp["attn"], a, lc, cfg,
                                              kind=kind, window=window,
                                              rt=rt, **akw)
                out_c = (nc["k"], nc["v"], nc["pos"])
            h = h + a
            m = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
            if "moe" in bp:
                y, _ = moe_mod.moe_ffn(bp["moe"], m, cfg, mesh=rt.mesh,
                                       ep_axis=rt.ep_axis,
                                       batch_axes=rt.batch_axes)
            else:
                y = swiglu(bp["mlp"], m)
            return h + y, out_c

        if is_mla:
            xs = (params["blocks"], cache["c_kv"], cache["k_rope"])
            x, (nck, nkr) = jax.lax.scan(body, x, xs)
            new_cache = dict(cache, c_kv=nck, k_rope=nkr)
        else:
            xs = (params["blocks"], cache["k"], cache["v"], cache["pos"])
            x, (nk, nv, np_) = jax.lax.scan(body, x, xs)
            new_cache = dict(cache, k=nk, v=nv, pos=np_)

    new_lens = lens + 1 if step_mask is None \
        else jnp.where(step_mask, lens + 1, lens)
    new_cache["len"] = new_lens
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = linear(x, params["lm_head"])
    return logits, new_cache


__all__ = ["Runtime", "init_params", "forward", "decode_step",
           "decode_step_slots", "prefill", "init_cache"]
