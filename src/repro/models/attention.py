"""Attention: GQA (full / sliding-window / chunked-local / bidirectional /
cross) + KV-cache decode, and DeepSeek-V2 MLA with absorbed decode.

The training/prefill path is a blockwise online-softmax implementation
(lax.scan over KV blocks) so S x S score matrices are never materialised —
this is also the pure-jnp oracle mirrored by the Pallas flash kernel in
``repro.kernels.flash_attention``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    apply_rope,
    linear,
    make_linear,
    make_rms_norm,
    rms_norm,
)

Array = jax.Array
KV_BLOCK = 1024
NEG_INF = -1e30


# ======================================================================
# mask helpers
def _mask_bias(q_pos: Array, k_pos: Array, kind: str, window: int,
               kv_len: Optional[Array]) -> Array:
    """(..., T, S_blk) additive bias. kind: causal|sliding|chunked|full."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if kind == "full":
        ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    elif kind == "causal":
        ok = kp <= qp
    elif kind == "sliding":
        ok = (kp <= qp) & (qp - kp < window)
    elif kind == "chunked":
        ok = (kp <= qp) & (qp // window == kp // window)
    else:
        raise ValueError(kind)
    if kv_len is not None:
        ok = ok & (kp < kv_len)
    return jnp.where(ok, 0.0, NEG_INF)


# ======================================================================
# sharding hints (perf: pins attention internals to head-on-model sharding,
# preventing XLA SPMD from resharding the score/prob tensors every KV block
# — see EXPERIMENTS.md §Perf iteration 1)
def _hint(x: Array, rt, spec_dims) -> Array:
    """spec_dims: tuple of 'batch' | 'model' | None per dim; each entry is
    applied only if the dim divides the axis size (else dropped)."""
    if rt is None or getattr(rt, "mesh", None) is None:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    mesh = rt.mesh
    parts = []
    for dim, want in enumerate(spec_dims):
        if want == "batch":
            axes = tuple(a for a in getattr(rt, "batch_axes", ())
                         if a in mesh.shape)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            parts.append(axes if (axes and size > 1
                                  and x.shape[dim] % size == 0) else None)
        elif want == "model":
            if "model" in getattr(rt, "batch_axes", ()):
                parts.append(None)   # model axis already carries batch (dp)
                continue
            size = mesh.shape.get("model", 1)
            if size > 1 and x.shape[dim] % size:
                # cannot satisfy the intended sharding: constraining would
                # force replication, which measured WORSE than XLA's own
                # choice (smollm h=9, §Perf iter 1) — leave unconstrained.
                return x
            parts.append("model" if size > 1 else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


# ======================================================================
# blockwise online-softmax attention (the jnp oracle; memory O(T * block)).
# Heads are processed FLAT (GQA K/V repeated per block — block-local, so
# the repeat never hits HBM at full length): flat H shards cleanly over the
# model axis where the grouped (KV=8, rep=4) layout cannot split 16 ways.
def blockwise_attention(q: Array, k: Array, v: Array, *,
                        kind: str = "causal", window: int = 0,
                        q_positions: Optional[Array] = None,
                        kv_positions: Optional[Array] = None,
                        kv_len: Optional[Array] = None,
                        kv_block: int = KV_BLOCK,
                        scale: Optional[float] = None,
                        rt=None) -> Array:
    """q: (B,T,H,dh); k,v: (B,S,KV,dh) with H = KV*rep. Returns (B,T,H,dh)."""
    b, t, h, dh = q.shape
    s, n_kv = k.shape[1], k.shape[2]
    rep = h // n_kv
    if rt is not None and getattr(rt, "kv_block", 0):
        kv_block = rt.kv_block
    scale = scale if scale is not None else dh ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    if kv_positions is None:
        kv_positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)

    kv_block = min(kv_block, s)
    pad = (-s) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=jnp.iinfo(jnp.int32).max // 2)
    n_blk = (s + pad) // kv_block

    # grouped einsum: GQA K/V stay un-repeated (measured better for GQA
    # archs than flat-head + hints — §Perf mistral iters 1-2); MLA (flat by
    # construction) keeps its hinted path in mla_forward.
    qg = q.reshape(b, t, n_kv, rep, dh) * scale
    kb = k.reshape(b, n_blk, kv_block, n_kv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blk, kv_block, n_kv, dh).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(b, n_blk, kv_block).transpose(1, 0, 2)

    def body(carry, blk):
        m, l, acc = carry
        kj, vj, posj = blk
        sc = jnp.einsum("btgrd,bsgd->bgrts", qg, kj.astype(qg.dtype),
                        preferred_element_type=jnp.float32)
        bias = _mask_bias(q_positions[:, None, None, :],
                          posj[:, None, None, :], kind, window, kv_len)
        sc = sc + bias.astype(jnp.float32)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bgrts,bsgd->btgrd", p.astype(vj.dtype), vj)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None].astype(acc.dtype) \
            + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n_kv, rep, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, rep, t), jnp.float32)
    acc0 = jnp.zeros((b, t, n_kv, rep, dh), v.dtype)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, pb))
    denom = l.transpose(0, 3, 1, 2)[..., None]
    out = acc.astype(jnp.float32) / jnp.maximum(denom, 1e-30)
    return out.reshape(b, t, h, dh).astype(q.dtype)


def direct_attention(q, k, v, **kw):
    """Single-block reference (used for small shapes / tests)."""
    return blockwise_attention(q, k, v, kv_block=max(k.shape[1], 1), **kw)


# ======================================================================
# GQA module
def make_gqa(key, cfg: ModelConfig, dtype, *, n_heads=None, n_kv=None,
             cross: bool = False) -> dict:
    h = n_heads or cfg.n_heads
    kvh = n_kv or cfg.n_kv_heads
    d, dh = cfg.d_model, cfg.head_dim
    kq, kk, kv_, ko = jax.random.split(key, 4)
    p = {
        "wq": make_linear(kq, d, h * dh, dtype),
        "wk": make_linear(kk, d, kvh * dh, dtype),
        "wv": make_linear(kv_, d, kvh * dh, dtype),
        "wo": make_linear(ko, h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = make_rms_norm(dh, dtype)
        p["k_norm"] = make_rms_norm(dh, dtype)
    return p


def _qkv(p: dict, x: Array, x_kv: Array, cfg: ModelConfig, h: int, kvh: int):
    b, t = x.shape[:2]
    s = x_kv.shape[1]
    q = linear(x, p["wq"]).reshape(b, t, h, cfg.head_dim)
    k = linear(x_kv, p["wk"]).reshape(b, s, kvh, cfg.head_dim)
    v = linear(x_kv, p["wv"]).reshape(b, s, kvh, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    return q, k, v


def gqa_forward(p: dict, x: Array, cfg: ModelConfig, *,
                kind: str = "causal", window: int = 0,
                positions: Optional[Array] = None,
                x_cross: Optional[Array] = None,
                n_heads=None, n_kv=None, rope: bool = True,
                return_kv: bool = False, rt=None):
    """Full-sequence (train/prefill) attention."""
    h = n_heads or cfg.n_heads
    kvh = n_kv or cfg.n_kv_heads
    b, t = x.shape[:2]
    x_kv = x_cross if x_cross is not None else x
    q, k, v = _qkv(p, x, x_kv, cfg, h, kvh)
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    if rope and cfg.rope_theta > 0 and x_cross is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = blockwise_attention(
        q, k, v, kind=("full" if x_cross is not None else kind), window=window,
        q_positions=positions, rt=rt,
        kv_positions=None if x_cross is None else
        jnp.arange(x_kv.shape[1], dtype=jnp.int32)[None].repeat(b, 0))
    y = linear(out.reshape(b, t, h * cfg.head_dim), p["wo"])
    if return_kv:
        return y, {"k": k, "v": v}          # k already rope'd (cache layout)
    return y


# ----------------------------------------------------------------------
# KV cache (decode). Ring buffer when window > 0 (sliding window / chunked).
def init_kv_cache(batch: int, cache_len: int, n_kv: int, head_dim: int,
                  dtype) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        # absolute position per slot (for rope'd keys the slot stores its
        # pos). Empty slots hold a huge sentinel so kp<=qp masks them out.
        "pos": jnp.full((batch, cache_len), jnp.iinfo(jnp.int32).max // 2,
                        jnp.int32),
        "len": jnp.zeros((), jnp.int32),       # tokens seen so far
    }


def gqa_decode(p: dict, x: Array, cache: dict, cfg: ModelConfig, *,
               kind: str = "causal", window: int = 0,
               n_heads=None, n_kv=None, rt=None) -> Tuple[Array, dict]:
    """One-token decode. x: (B, 1, d_model)."""
    h = n_heads or cfg.n_heads
    kvh = n_kv or cfg.n_kv_heads
    b = x.shape[0]
    cache_len = cache["k"].shape[1]
    pos = cache["len"]                                    # scalar int32
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, x, cfg, h, kvh)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # ring buffer for windowed attention, linear buffer otherwise
    slot = (pos % cache_len) if window > 0 else jnp.minimum(pos, cache_len - 1)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    pos_cache = jax.lax.dynamic_update_slice(
        cache["pos"], positions, (0, slot))
    # empty slots carry a huge position sentinel, so kp<=qp masks them
    out = blockwise_attention(
        q, k_cache, v_cache, kind=kind, window=window or cache_len,
        q_positions=positions, kv_positions=pos_cache, rt=rt)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache, "len": pos + 1}
    o = linear(out.reshape(b, 1, h * cfg.head_dim), p["wo"])
    return o, new_cache


def gqa_decode_slots(p: dict, x: Array, cache: dict, cfg: ModelConfig, *,
                     kind: str = "causal", window: int = 0,
                     n_heads=None, n_kv=None, rt=None,
                     backend: str = "reference",
                     interpret: bool = False) -> Tuple[Array, dict]:
    """One-token decode with PER-SLOT positions (the serving cache pool).

    Unlike ``gqa_decode`` (one scalar ``len`` for the whole batch), every
    slot carries its own position: x: (S, 1, d_model); cache: ``k``/``v``
    (S, C, KV, dh), ``pos`` (S, C), ``lens`` (S,) int32.  Slot s writes its
    new K/V at ring index ``lens[s] % C`` (windowed) or ``lens[s]``
    (linear) and attends at query position ``lens[s]`` — slots at
    different depths coexist in one batched call, which is what lets new
    requests be admitted mid-decode without recompiling.

    ``backend='pallas'`` routes the attention contraction to
    ``kernels.decode_attention`` (interpret mode off-TPU); the default is
    the blockwise jnp oracle.
    """
    h = n_heads or cfg.n_heads
    kvh = n_kv or cfg.n_kv_heads
    b = x.shape[0]
    cache_len = cache["k"].shape[1]
    lens = cache["lens"]                                  # (S,) int32
    positions = lens[:, None]                             # (S, 1)
    q, k, v = _qkv(p, x, x, cfg, h, kvh)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    slot = (lens % cache_len) if window > 0 \
        else jnp.minimum(lens, cache_len - 1)
    rows = jnp.arange(b, dtype=jnp.int32)
    k_cache = cache["k"].at[rows, slot].set(k[:, 0])
    v_cache = cache["v"].at[rows, slot].set(v[:, 0])
    pos_cache = cache["pos"].at[rows, slot].set(lens)
    if backend == "pallas":
        from repro.kernels.decode_attention import decode_attention_pallas
        out = decode_attention_pallas(q[:, 0], k_cache, v_cache, lens,
                                      pos_cache, window=window,
                                      interpret=interpret)[:, None]
    else:
        out = blockwise_attention(q, k_cache, v_cache, kind=kind,
                                  window=window or cache_len,
                                  q_positions=positions,
                                  kv_positions=pos_cache, rt=rt)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache,
                 "lens": lens + 1}
    o = linear(out.reshape(b, 1, h * cfg.head_dim), p["wo"])
    return o, new_cache


def gqa_cross_decode(p: dict, x: Array, cross_cache: dict,
                     cfg: ModelConfig, *, n_heads=None, n_kv=None) -> Array:
    """Cross-attention during decode: kv precomputed from the encoder."""
    h = n_heads or cfg.n_heads
    b = x.shape[0]
    q = linear(x, p["wq"]).reshape(b, 1, h, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
    out = blockwise_attention(q, cross_cache["k"], cross_cache["v"],
                              kind="full")
    return linear(out.reshape(b, 1, h * cfg.head_dim), p["wo"])


def precompute_cross_kv(p: dict, x_enc: Array, cfg: ModelConfig, *,
                        n_kv=None) -> dict:
    kvh = n_kv or cfg.n_kv_heads
    b, s = x_enc.shape[:2]
    k = linear(x_enc, p["wk"]).reshape(b, s, kvh, cfg.head_dim)
    v = linear(x_enc, p["wv"]).reshape(b, s, kvh, cfg.head_dim)
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    return {"k": k, "v": v}


# ======================================================================
# DeepSeek-V2 MLA [arXiv:2405.04434]
def make_mla(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    keys = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = make_linear(keys[0], d, m.q_lora_rank, dtype)
        p["q_norm"] = make_rms_norm(m.q_lora_rank, dtype)
        p["wq_b"] = make_linear(keys[1], m.q_lora_rank, h * qd, dtype)
    else:
        p["wq"] = make_linear(keys[0], d, h * qd, dtype)
    p["w_dkv"] = make_linear(keys[2], d, m.kv_lora_rank + m.rope_head_dim, dtype)
    p["kv_norm"] = make_rms_norm(m.kv_lora_rank, dtype)
    p["w_ukv"] = make_linear(keys[3], m.kv_lora_rank,
                             h * (m.nope_head_dim + m.v_head_dim), dtype)
    p["wo"] = make_linear(keys[4], h * m.v_head_dim, d, dtype)
    return p


def _mla_q(p: dict, x: Array, cfg: ModelConfig, positions: Array):
    m = cfg.mla
    b, t = x.shape[:2]
    h = cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    if "wq_a" in p:
        ql = rms_norm(linear(x, p["wq_a"]), p["q_norm"]["scale"], cfg.norm_eps)
        q = linear(ql, p["wq_b"]).reshape(b, t, h, qd)
    else:
        q = linear(x, p["wq"]).reshape(b, t, h, qd)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p: dict, x: Array, cfg: ModelConfig, positions: Array):
    m = cfg.mla
    ckv_rope = linear(x, p["w_dkv"])
    c_kv, k_rope = jnp.split(ckv_rope, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_forward(p: dict, x: Array, cfg: ModelConfig, *,
                positions: Optional[Array] = None,
                kv_block: int = KV_BLOCK, return_kv: bool = False,
                rt=None):
    """Train/prefill MLA: blockwise attention, up-projecting K/V lazily per
    KV block inside the scan (never materialises full K/V)."""
    m = cfg.mla
    b, t = x.shape[:2]
    h = cfg.n_heads
    if rt is not None and getattr(rt, "kv_block", 0):
        kv_block = rt.kv_block
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)       # (b,t,h,*)
    c_kv, k_rope = _mla_ckv(p, x, cfg, positions)       # (b,t,kvr),(b,t,rd)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5

    s = t
    kv_block = min(kv_block, s)
    pad = (-s) % kv_block
    if pad:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    n_blk = (s + pad) // kv_block
    ckv_b = c_kv.reshape(b, n_blk, kv_block, -1).transpose(1, 0, 2, 3)
    krope_b = k_rope.reshape(b, n_blk, kv_block, -1).transpose(1, 0, 2, 3)
    pos_b = jnp.pad(positions, ((0, 0), (0, pad)),
                    constant_values=jnp.iinfo(jnp.int32).max // 2
                    ).reshape(b, n_blk, kv_block).transpose(1, 0, 2)
    w_ukv = p["w_ukv"]["w"]

    def body(carry, blk):
        mx, l, acc = carry
        ckv_j, kr_j, pos_j = blk
        kv = (ckv_j @ w_ukv.astype(ckv_j.dtype)).reshape(
            b, kv_block, h, m.nope_head_dim + m.v_head_dim)
        k_nope, v = jnp.split(kv, [m.nope_head_dim], axis=-1)
        sc = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bthd,bsd->bhts", q_rope, kr_j,
                           preferred_element_type=jnp.float32)) * scale
        sc = _hint(sc, rt, ("batch", "model", None, None))
        bias = _mask_bias(positions[:, None, :], pos_j[:, None, :],
                          "causal", 0, None)
        sc = sc + bias.astype(jnp.float32)
        m_new = jnp.maximum(mx, sc.max(axis=-1))
        pr = _hint(jnp.exp(sc - m_new[..., None]), rt,
                   ("batch", "model", None, None))
        corr = jnp.exp(mx - m_new)
        l_new = l * corr + pr.sum(axis=-1)
        pv = jnp.einsum("bhts,bshd->bthd", pr.astype(v.dtype), v)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, t), jnp.float32)
    acc0 = jnp.zeros((b, t, h, m.v_head_dim), x.dtype)
    (mx, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                   (ckv_b, krope_b, pos_b))
    out = acc.astype(jnp.float32) / jnp.maximum(
        l.transpose(0, 2, 1)[..., None], 1e-30)
    out = out.reshape(b, t, h * m.v_head_dim).astype(x.dtype)
    y = linear(out, p["wo"])
    if return_kv:
        return y, {"c_kv": c_kv[:, :t], "k_rope": k_rope[:, :t]}
    return y


def init_mla_cache(batch: int, cache_len: int, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, m.rope_head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def mla_decode_slots(p: dict, x: Array, cache: dict, cfg: ModelConfig,
                     rt=None) -> Tuple[Array, dict]:
    """Absorbed MLA decode with PER-SLOT positions (serving cache pool).
    cache: ``c_kv`` (S, C, kvr), ``k_rope`` (S, C, rd), ``lens`` (S,)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    lens = cache["lens"]                                  # (S,)
    positions = lens[:, None]                             # (S, 1)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_new, kr_new = _mla_ckv(p, x, cfg, positions)
    rows = jnp.arange(b, dtype=jnp.int32)
    c_cache = cache["c_kv"].at[rows, lens].set(c_new[:, 0])
    kr_cache = cache["k_rope"].at[rows, lens].set(kr_new[:, 0])

    w_ukv = p["w_ukv"]["w"].reshape(m.kv_lora_rank, h,
                                    m.nope_head_dim + m.v_head_dim)
    w_uk = w_ukv[..., : m.nope_head_dim]
    w_uv = w_ukv[..., m.nope_head_dim:]
    q_c = jnp.einsum("bthd,chd->bhc", q_nope, w_uk.astype(q_nope.dtype))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    sc = (jnp.einsum("bhc,bsc->bhs", q_c, c_cache,
                     preferred_element_type=jnp.float32)
          + jnp.einsum("bthd,bsd->bhs", q_rope, kr_cache,
                       preferred_element_type=jnp.float32)) * scale
    s_len = c_cache.shape[1]
    valid = jnp.arange(s_len)[None, None, :] <= lens[:, None, None]
    sc = jnp.where(valid, sc, NEG_INF)
    alpha = jax.nn.softmax(sc, axis=-1).astype(c_cache.dtype)
    o_c = jnp.einsum("bhs,bsc->bhc", alpha, c_cache)
    out = jnp.einsum("bhc,chd->bhd", o_c, w_uv.astype(o_c.dtype))
    out = out.reshape(b, 1, h * m.v_head_dim)
    new_cache = {"c_kv": c_cache, "k_rope": kr_cache, "lens": lens + 1}
    return linear(out, p["wo"]), new_cache


def mla_decode(p: dict, x: Array, cache: dict, cfg: ModelConfig,
               rt=None) -> Tuple[Array, dict]:
    """Absorbed MLA decode: attention runs in the compressed kv_lora space —
    the cache stays (S, 512+64) per token and K/V are never up-projected."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    pos = cache["len"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)         # (b,1,h,*)
    c_new, kr_new = _mla_ckv(p, x, cfg, positions)        # (b,1,kvr),(b,1,rd)
    c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, pos, 0))

    w_ukv = p["w_ukv"]["w"].reshape(m.kv_lora_rank, h,
                                    m.nope_head_dim + m.v_head_dim)
    w_uk = w_ukv[..., : m.nope_head_dim]                  # (kvr, h, nope)
    w_uv = w_ukv[..., m.nope_head_dim:]                   # (kvr, h, v)
    # absorb: q_c = q_nope @ W_uk^T  -> (b, h, kvr)
    q_c = jnp.einsum("bthd,chd->bhc", q_nope, w_uk.astype(q_nope.dtype))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    sc = (jnp.einsum("bhc,bsc->bhs", q_c, c_cache,
                     preferred_element_type=jnp.float32)
          + jnp.einsum("bthd,bsd->bhs", q_rope, kr_cache,
                       preferred_element_type=jnp.float32)) * scale
    # decode sequence-parallelism: scores/weights sharded over cache
    # positions (matches the S-sharded MLA cache layout); the softmax and
    # the o_c contraction reduce over S -> small cross-shard psums only
    sc = _hint(sc, rt, ("batch", None, "model"))
    s_len = c_cache.shape[1]
    valid = jnp.arange(s_len)[None, None, :] <= pos
    sc = jnp.where(valid, sc, NEG_INF)
    alpha = _hint(jax.nn.softmax(sc, axis=-1).astype(c_cache.dtype),
                  rt, ("batch", None, "model"))
    o_c = jnp.einsum("bhs,bsc->bhc", alpha, c_cache)      # (b,h,kvr)
    out = jnp.einsum("bhc,chd->bhd", o_c, w_uv.astype(o_c.dtype))
    out = out.reshape(b, 1, h * m.v_head_dim)
    new_cache = {"c_kv": c_cache, "k_rope": kr_cache, "len": pos + 1}
    return linear(out, p["wo"]), new_cache
