"""Latent anchor-proximity (LAP) uncertainty + precision weights (Eq. 6).

u(x) = 0.5 * (1 - max_j cos(Pool(z_x), Pool(z_aj)))  in [0, 1]:
samples projecting into latent voids far from every public anchor get
u ~ 1 (high epistemic uncertainty).  Node weight p_k is the mean inverse
uncertainty over its local data, normalised across nodes by the server —
the paper's precision-weighted alternative to FedAvg.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def lap_uncertainty(pooled_samples: Array, pooled_anchors: Array,
                    eps: float = 1e-8) -> Array:
    """(N, D), (B, D) -> (N,) uncertainties in [0, 1]."""
    z = pooled_samples.astype(jnp.float32)
    a = pooled_anchors.astype(jnp.float32)
    zn = z / jnp.sqrt(jnp.maximum((z * z).sum(-1, keepdims=True), eps))
    an = a / jnp.sqrt(jnp.maximum((a * a).sum(-1, keepdims=True), eps))
    sim = zn @ an.T                                   # (N, B)
    return 0.5 * (1.0 - sim.max(axis=-1))


def node_precision(uncertainties: Array, floor: float = 1e-3) -> Array:
    """Unnormalised p_k = mean_i u^-1(x_i) over one node's local samples."""
    return (1.0 / jnp.maximum(uncertainties, floor)).mean()


def batched_precisions(pooled_samples: Array, pooled_anchors: Array) -> Array:
    """Node-stacked LAP precisions: (K, N, D), (K, B, D) -> (K,)
    unnormalised p_k, the vmapped form the round engine uploads."""
    u = jax.vmap(lap_uncertainty)(pooled_samples, pooled_anchors)
    return jax.vmap(node_precision)(u)


def precision_weights(node_precisions: Array) -> Array:
    """Server: normalise per-node precisions into aggregation weights
    (the paper's 1/E factor)."""
    p = jnp.maximum(node_precisions.astype(jnp.float32), 0.0)
    return p / jnp.maximum(p.sum(), 1e-12)


def masked_precision_weights(node_precisions: Array, mask: Array) -> Array:
    """Masked LAP precision upload (partial participation): only REPORTING
    nodes (``mask`` (K,) 0/1) contribute their precision, and the
    normalisation runs over the reporting cohort — non-reporters get
    exactly zero aggregation weight.  Reduces to ``precision_weights``
    under a full mask."""
    p = jnp.maximum(node_precisions.astype(jnp.float32), 0.0) \
        * mask.astype(jnp.float32)
    return p / jnp.maximum(p.sum(), 1e-12)


def staleness_factor(lag: Array, schedule: str = "poly",
                     alpha: float = 1.0,
                     max_staleness: int = None) -> Array:
    """FedBuff-style staleness discount f(lag) in [0, 1] for reports that
    arrive ``lag`` rounds after they were computed:

      - ``poly``:   (1 + lag)^-alpha  — smooth polynomial decay;
      - ``cutoff``: 1 while lag <= max_staleness, else 0 — bounded
        staleness (requires ``max_staleness``).

    With ``poly``, ``max_staleness`` additionally hard-gates the factor
    to zero past the bound.  Pure jax, elementwise over (K,) int lags."""
    lag = jnp.maximum(lag.astype(jnp.float32), 0.0)
    if schedule == "poly":
        f = jnp.power(1.0 + lag, -float(alpha))
    elif schedule == "cutoff":
        if max_staleness is None:
            raise ValueError("staleness schedule 'cutoff' needs a "
                             "max_staleness bound")
        f = jnp.ones_like(lag)
    else:
        raise ValueError(f"unknown staleness schedule {schedule!r}")
    if max_staleness is not None:
        f = f * (lag <= float(max_staleness)).astype(jnp.float32)
    return f


def stale_precision_weights(node_precisions: Array, lag: Array,
                            mask: Array, schedule: str = "poly",
                            alpha: float = 1.0,
                            max_staleness: int = None) -> Array:
    """Staleness-weighted precision averaging (the async server step):
    weight_k = p_k * f(lag_k) over the DELIVERED reports (``mask`` (K,)
    0/1), normalised over the delivered cohort.  A round with no
    deliveries (or all deliveries staled out) returns all-zero weights —
    the caller keeps the previous global value.  Reduces to
    ``masked_precision_weights`` at lag == 0."""
    f = staleness_factor(lag, schedule, alpha, max_staleness)
    p = jnp.maximum(node_precisions.astype(jnp.float32), 0.0) \
        * mask.astype(jnp.float32) * f
    s = p.sum()
    return jnp.where(s > 0.0, p / jnp.maximum(s, 1e-12),
                     jnp.zeros_like(p))
