"""Federated rounds for unpaired multimodal data — the paper's protocol.

Per round, each node k (one modality each, strictly private data):
  1. runs local AdamW steps on  L_task + lambda * (1 - CKA(G_k, G_bar))
     (Eq. 3), where only the GeoLoRA ``lora_B`` / GeoDoRA ``dora_m`` /
     shared-head params and the LOCAL adapter W_mk are trainable;
     under GeoDoRA the geometric loss sees ``stop_gradient(dora_m)`` so it
     constrains *direction only* (paper: "R_geo applied exclusively to D");
  2. computes its public-anchor Gram matrix G_k (Eq. 1) and its LAP
     precision p_k (Eq. 6) — the ONLY things uploaded besides the side-cars;
  3. the server averages Grams into G_bar, computes precision weights, and
     precision-weight-averages the shipped side-cars (Eqs. 4-5), then
     broadcasts.

Adapters W_mk never leave the node; the frozen base theta is never
communicated after initialisation.  Communication per round is measured and
compared against full-model FedAvg in the benchmarks (paper claim: >99.9%
reduction).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, get_config
from repro.core import aggregation as agg
from repro.core import cka as cka_mod
from repro.core import lora as lora_mod
from repro.core import uncertainty as unc
from repro.data.synthetic import SyntheticMultimodal
from repro.data.tokenizers import FrozenTokenizer, default_tokenizers
from repro.models import transformer as T
from repro.models.common import cross_entropy_loss, linear, make_linear
from repro.optim.adamw import AdamW

Array = jax.Array


@dataclass(frozen=True)
class FederationConfig:
    n_nodes: int = 4
    modalities: Tuple[str, ...] = ("image", "text", "genetics", "tabular")
    method: str = "geolora"            # geolora | geodora | fedavg_full
    aggregation: str = "precision"     # precision | uniform
    lora_rank: int = 8
    lambda_geo: float = 1.0
    rounds: int = 5
    local_steps: int = 10
    local_batch: int = 32
    lr: float = 3e-3
    n_classes: int = 8
    anchors_per_class: int = 4
    n_tokens: int = 16
    corrupt_nodes: Tuple[int, ...] = ()
    # bridge clients (paper's hybrid federation): nodes holding locally
    # PAIRED data across two modalities add an intra-node contrastive loss,
    # rigidifying the global manifold alignment.
    bridge_nodes: Tuple[int, ...] = ()
    bridge_modality: str = "text"            # second modality on bridges
    lambda_bridge: float = 0.5
    # nodes whose anchor modality is MISSING from the public set and is
    # replaced by noisy synthetic anchors (digital twins); the paper claims
    # LAP naturally downweights them via the distributional shift.
    synthetic_anchor_nodes: Tuple[int, ...] = ()
    synthetic_anchor_noise: float = 2.0
    seed: int = 0
    center_cka: bool = False


def _stopgrad_named(tree, names=("dora_m",)):
    def walk(node, name):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name) for v in node)
        if node is None:
            return None
        return jax.lax.stop_gradient(node) if name in names else node
    return walk(tree, "")


def _shipped_mask(trainable):
    """True for side-cars shipped to the server (lora_B/dora_m/cls_head),
    False for node-local params (adapter W_mk)."""
    def walk(node, name, local):
        local = local or name in lora_mod.LOCAL_SUBTREES
        if isinstance(node, dict):
            return {k: walk(v, k, local) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name, local) for v in node)
        if node is None:
            return None
        return not local
    return walk(trainable, "", False)


def _split_by_mask(tree, mask):
    a = jax.tree.map(lambda p, m: p if (p is not None and m) else None,
                     tree, mask, is_leaf=lambda x: x is None)
    b = jax.tree.map(lambda p, m: p if (p is not None and not m) else None,
                     tree, mask, is_leaf=lambda x: x is None)
    return a, b


def _merge_by_mask(shipped, local, mask):
    return jax.tree.map(
        lambda m, s, l: s if m else l, mask, shipped, local,
        is_leaf=lambda x: x is None)


class Federation:
    """Simulated federation (K nodes on one host). The multi-pod SPMD
    mapping of the same protocol lives in repro.launch."""

    def __init__(self, fed: FederationConfig, model: ModelConfig = None):
        self.fed = fed
        self.cfg = model or get_config("fedmm-small")
        key = jax.random.PRNGKey(fed.seed)
        k_model, k_data, k_anchor, k_lora, k_nodes = jax.random.split(key, 5)

        # ---- substrate: task, tokenizers, anchors ----
        from repro.configs.fedmm_base import MODALITY_TOKENIZER_DIMS
        self.task = SyntheticMultimodal(n_classes=fed.n_classes,
                                        modalities=fed.modalities,
                                        seed=fed.seed)
        self.tokenizers = default_tokenizers(
            {m: MODALITY_TOKENIZER_DIMS[m] for m in fed.modalities},
            self.task.d_raw, fed.n_tokens, seed=fed.seed)
        anchors_raw = self.task.anchor_set(k_anchor, fed.anchors_per_class)
        # pre-tokenize public anchors once per modality (tokenizers frozen)
        self.anchor_tokens = {m: self.tokenizers[m](anchors_raw[m][0])
                              for m in fed.modalities}
        # synthetic (generated) anchors: same class structure, heavy noise
        self.synthetic_anchor_tokens = {}
        if fed.synthetic_anchor_nodes:
            kn = jax.random.fold_in(k_anchor, 777)
            for m, (raw, _) in anchors_raw.items():
                noisy = raw + fed.synthetic_anchor_noise * \
                    jax.random.normal(jax.random.fold_in(
                        kn, hash(m) % (2 ** 31)), raw.shape)
                self.synthetic_anchor_tokens[m] = self.tokenizers[m](noisy)

        # ---- global model (the paper's VLM-initialised homogeneous
        # transformer; random init here — protocol math is init-agnostic) ----
        params = T.init_params(k_model, self.cfg)
        if fed.method in ("geolora", "geodora"):
            spec = lora_mod.LoRASpec(rank=fed.lora_rank,
                                     dora=(fed.method == "geodora"))
            params = lora_mod.attach_lora(k_lora, params, spec)
        kh = jax.random.fold_in(k_model, 99)
        params["cls_head"] = make_linear(kh, self.cfg.d_model, fed.n_classes,
                                         jnp.float32)

        if fed.method == "fedavg_full":
            mask = jax.tree.map(lambda _: True, params)
        else:
            mask = lora_mod.trainable_mask(params)
        self.mask = mask
        trainable, self.frozen = lora_mod.partition(params, mask)

        # ---- per-node state: shared trainables + local adapter ----
        self.node_modality = [fed.modalities[i % len(fed.modalities)]
                              for i in range(fed.n_nodes)]
        self.opt = AdamW(lr=fed.lr, weight_decay=0.0, grad_clip=1.0)
        self.nodes = []
        for i in range(fed.n_nodes):
            m = self.node_modality[i]
            ka = jax.random.fold_in(k_nodes, i)
            node_train = dict(trainable)
            node_train["adapter"] = make_linear(
                ka, self.tokenizers[m].d_out, self.cfg.d_model, jnp.float32)
            self.nodes.append({
                "trainable": node_train,
                "opt_state": self.opt.init(node_train),
                "modality": m,
                "corrupt": i in fed.corrupt_nodes,
                "bridge": i in fed.bridge_nodes,
                "key": jax.random.fold_in(k_data, i),
            })
        # bridge clients get a second local adapter for the paired modality
        for node in self.nodes:
            if node["bridge"]:
                m2 = fed.bridge_modality
                if m2 == node["modality"]:
                    m2 = next(m for m in fed.modalities
                              if m != node["modality"])
                node["modality2"] = m2
                ka2 = jax.random.fold_in(k_nodes, 1000 + self.nodes.index(node))
                node["trainable"]["adapter2"] = make_linear(
                    ka2, self.tokenizers[m2].d_out, self.cfg.d_model,
                    jnp.float32)
                node["opt_state"] = self.opt.init(node["trainable"])
        # frozen tree needs structure-matching adapter placeholders
        self.frozen = dict(self.frozen)
        self.frozen["adapter"] = {"w": None}
        self.mask = dict(self.mask)
        self.mask["adapter"] = {"w": True}
        if any(n.get("bridge") for n in self.nodes):
            self.frozen_bridge = dict(self.frozen, adapter2={"w": None})
        else:
            self.frozen_bridge = None

        self.gbar = self._initial_consensus()
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    def _pooled(self, params, tokens) -> Array:
        embeds = linear(tokens.astype(jnp.float32), params["adapter"])
        _, aux = T.forward(params, {"inputs_embeds": embeds}, self.cfg)
        return aux["pooled"]

    def _frozen_for(self, node) -> dict:
        return self.frozen_bridge if node.get("bridge") else self.frozen

    def _initial_consensus(self) -> Array:
        grams = []
        for node in self.nodes:
            params = lora_mod.combine(node["trainable"],
                                      self._frozen_for(node))
            pooled = self._pooled(params, self.anchor_tokens[node["modality"]])
            grams.append(cka_mod.cosine_gram(pooled))
        return cka_mod.consensus_gram(jnp.stack(grams))

    # ------------------------------------------------------------------
    @staticmethod
    def _contrastive(z1: Array, z2: Array, tau: float = 0.2) -> Array:
        """Intra-node InfoNCE on locally PAIRED samples (bridge clients)."""
        z1 = z1 / jnp.maximum(jnp.linalg.norm(z1, axis=-1, keepdims=True),
                              1e-8)
        z2 = z2 / jnp.maximum(jnp.linalg.norm(z2, axis=-1, keepdims=True),
                              1e-8)
        sim = (z1 @ z2.T) / tau
        labels = jnp.arange(z1.shape[0])
        return 0.5 * (cross_entropy_loss(sim, labels)
                      + cross_entropy_loss(sim.T, labels))

    @functools.partial(jax.jit, static_argnums=(0,))
    def _local_step(self, trainable, opt_state, frozen, batch_tokens, labels,
                    anchor_tokens, gbar):
        lam = self.fed.lambda_geo

        def loss_fn(train):
            params = lora_mod.combine(train, frozen)
            pooled = self._pooled(params, batch_tokens)
            logits = linear(pooled, params["cls_head"])
            task = cross_entropy_loss(logits, labels)
            # GeoDoRA: geometric loss constrains direction only
            params_geo = lora_mod.combine(_stopgrad_named(train), frozen)
            pooled_a = self._pooled(params_geo, anchor_tokens)
            geo = cka_mod.geo_alignment_loss(pooled_a, gbar,
                                             center=self.fed.center_cka)
            acc = (logits.argmax(-1) == labels).mean()
            return task + lam * geo, (task, geo, acc, pooled, pooled_a)

        grads, (task, geo, acc, pooled, pooled_a) = \
            jax.grad(loss_fn, has_aux=True)(trainable)
        new_train, new_opt = self.opt.update(grads, opt_state, trainable)
        return new_train, new_opt, {"task": task, "geo": geo, "acc": acc,
                                    "pooled": pooled, "pooled_a": pooled_a}

    @functools.partial(jax.jit, static_argnums=(0,))
    def _bridge_step(self, trainable, opt_state, frozen, batch_tokens,
                     batch_tokens2, labels, anchor_tokens, gbar):
        """Local step on a bridge client: task + geo + paired contrastive
        between the two local modalities (paper: 'bridge clients ...
        rigidify the global manifold alignment')."""
        lam, lam_b = self.fed.lambda_geo, self.fed.lambda_bridge

        def loss_fn(train):
            params = lora_mod.combine(train, frozen)
            pooled = self._pooled(params, batch_tokens)
            params2 = dict(params, adapter=params["adapter2"])
            pooled2 = self._pooled(params2, batch_tokens2)
            logits = linear(pooled, params["cls_head"])
            task = cross_entropy_loss(logits, labels)
            contrast = self._contrastive(pooled, pooled2)
            params_geo = lora_mod.combine(_stopgrad_named(train), frozen)
            pooled_a = self._pooled(params_geo, anchor_tokens)
            geo = cka_mod.geo_alignment_loss(pooled_a, gbar,
                                             center=self.fed.center_cka)
            acc = (logits.argmax(-1) == labels).mean()
            return task + lam * geo + lam_b * contrast, \
                (task, geo, acc, pooled, pooled_a)

        grads, (task, geo, acc, pooled, pooled_a) = \
            jax.grad(loss_fn, has_aux=True)(trainable)
        new_train, new_opt = self.opt.update(grads, opt_state, trainable)
        return new_train, new_opt, {"task": task, "geo": geo, "acc": acc,
                                    "pooled": pooled, "pooled_a": pooled_a}

    # ------------------------------------------------------------------
    def run_round(self) -> dict:
        fed = self.fed
        grams, precisions, shipped_list = [], [], []
        metrics = {"task": [], "geo": [], "acc": []}
        for i, node in enumerate(self.nodes):
            m = node["modality"]
            anchors = (self.synthetic_anchor_tokens[m]
                       if i in fed.synthetic_anchor_nodes
                       else self.anchor_tokens[m])
            last = None
            for s in range(fed.local_steps):
                node["key"], kb = jax.random.split(node["key"])
                raw, labels = self.task.sample(kb, m, fed.local_batch,
                                               corrupt=node["corrupt"])
                tokens = self.tokenizers[m](raw)
                if node.get("bridge"):
                    # locally paired: same latent draws through modality 2
                    m2 = node["modality2"]
                    raw2, _ = self.task.sample(kb, m2, fed.local_batch)
                    tokens2 = self.tokenizers[m2](raw2)
                    node["trainable"], node["opt_state"], last = \
                        self._bridge_step(
                            node["trainable"], node["opt_state"],
                            self.frozen_bridge, tokens, tokens2, labels,
                            anchors, self.gbar)
                else:
                    node["trainable"], node["opt_state"], last = \
                        self._local_step(
                            node["trainable"], node["opt_state"],
                            self.frozen, tokens, labels, anchors, self.gbar)
            metrics["task"].append(float(last["task"]))
            metrics["geo"].append(float(last["geo"]))
            metrics["acc"].append(float(last["acc"]))
            # upload: Gram + precision + shipped side-cars
            grams.append(cka_mod.cosine_gram(last["pooled_a"]))
            u = unc.lap_uncertainty(last["pooled"], last["pooled_a"])
            precisions.append(unc.node_precision(u))
            smask = _shipped_mask(node["trainable"])
            shipped, _ = _split_by_mask(node["trainable"], smask)
            # bridge nodes carry extra local-only keys (adapter2) that are
            # all-None in the shipped view — drop for structural uniformity
            shipped = {k: v for k, v in shipped.items()
                       if any(l is not None for l in jax.tree.leaves(
                           v, is_leaf=lambda x: x is None))}
            shipped_list.append(shipped)
            node["_smask"] = smask

        # ---- server ----
        grams = jnp.stack(grams)
        self.gbar = cka_mod.consensus_gram(grams)
        if fed.aggregation == "precision":
            weights = unc.precision_weights(jnp.stack(precisions))
        else:
            weights = jnp.full((fed.n_nodes,), 1.0 / fed.n_nodes)
        avg_shipped = agg.aggregate_geolora(shipped_list, weights)
        for node in self.nodes:
            merged = dict(avg_shipped)
            for k in node["trainable"]:
                if k not in merged:
                    merged[k] = jax.tree.map(lambda _: None,
                                             node["trainable"][k])
            node["trainable"] = _merge_by_mask(merged, node["trainable"],
                                               node["_smask"])

        pair_cka = cka_mod.pairwise_cka(grams, center=fed.center_cka)
        off_diag = (pair_cka.sum() - jnp.trace(pair_cka)) \
            / max(fed.n_nodes * (fed.n_nodes - 1), 1)
        shipped_bytes = agg.comm_bytes_per_round(
            shipped_list[0], gram_side=self.gbar.shape[0])
        full_bytes = lora_mod.param_bytes(
            lora_mod.combine(self.nodes[0]["trainable"],
                             self._frozen_for(self.nodes[0])))
        rec = {
            "task_loss": sum(metrics["task"]) / fed.n_nodes,
            "geo_loss": sum(metrics["geo"]) / fed.n_nodes,
            "acc": sum(metrics["acc"]) / fed.n_nodes,
            "cross_node_cka": float(off_diag),
            "weights": [float(w) for w in weights],
            "uplink_bytes": int(shipped_bytes),
            "full_model_bytes": int(full_bytes),
        }
        self.history.append(rec)
        return rec

    def run(self) -> List[dict]:
        for _ in range(self.fed.rounds):
            self.run_round()
        return self.history

    # ------------------------------------------------------------------
    # checkpointing: the server checkpoint is (consensus Gram + per-node
    # trainables + opt states) — the frozen base/tokenizers are rebuilt
    # deterministically from the config seed.
    def save(self, path: str) -> None:
        from repro.checkpoint import save_checkpoint
        state = {
            "gbar": self.gbar,
            "nodes": [{"trainable": n["trainable"],
                       "opt_state": n["opt_state"],
                       "key": n["key"]} for n in self.nodes],
        }
        save_checkpoint(path, state, step=len(self.history))

    def restore(self, path: str) -> int:
        from repro.checkpoint import load_checkpoint
        like = {
            "gbar": self.gbar,
            "nodes": [{"trainable": n["trainable"],
                       "opt_state": n["opt_state"],
                       "key": n["key"]} for n in self.nodes],
        }
        state, step = load_checkpoint(path, like)
        self.gbar = state["gbar"]
        for node, saved in zip(self.nodes, state["nodes"]):
            node["trainable"] = saved["trainable"]
            node["opt_state"] = saved["opt_state"]
            node["key"] = saved["key"]
        return step

    def node_params(self, i: int) -> dict:
        return lora_mod.combine(self.nodes[i]["trainable"],
                                self._frozen_for(self.nodes[i]))
