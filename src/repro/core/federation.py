"""Federated rounds for unpaired multimodal data — the paper's protocol.

Per round, each node k (one modality each, strictly private data):
  1. runs local AdamW steps on  L_task + lambda * (1 - CKA(G_k, G_bar))
     (Eq. 3), where only the GeoLoRA ``lora_B`` / GeoDoRA ``dora_m`` /
     shared-head params and the LOCAL adapter W_mk are trainable;
     under GeoDoRA the geometric loss sees ``stop_gradient(dora_m)`` so it
     constrains *direction only* (paper: "R_geo applied exclusively to D");
  2. computes its public-anchor Gram matrix G_k (Eq. 1) and its LAP
     precision p_k (Eq. 6) — the ONLY things uploaded besides the side-cars;
  3. the server averages Grams into G_bar, computes precision weights, and
     precision-weight-averages the shipped side-cars (Eqs. 4-5), then
     broadcasts.

Adapters W_mk never leave the node; the frozen base theta is never
communicated after initialisation.  Communication per round is measured and
compared against full-model FedAvg in the benchmarks (paper claim: >99.9%
reduction).

Execution engine
----------------
Two implementations share one substrate:

``SequentialFederation`` — the readable reference: a Python loop over nodes
and local steps, one jit dispatch per node per step (K x E per round).
Kept as the oracle for the engine-equivalence tests and benchmarks.

``Federation`` — the node-stacked engine (``repro.core.engine``), the
default.  Architecture:

  * **node axis**: per-node trainables, optimizer states and RNG keys are
    stacked along a leading axis; ``jax.vmap`` maps the local step across
    it and ``jax.lax.scan`` runs the E local steps.
  * **width bucketing** (the heterogeneous-width strategy): per-modality
    tokenizer widths differ per node (text 2048 .. tabular 192), and the
    paper's regime makes that the COMMON case.  Nodes are grouped by
    adapter width into W buckets; each bucket stacks only the nodes whose
    widths match (zero-padded to the bucket width — for a bridge node,
    the max of its two adapters' widths), so a narrow tabular node never
    pays the quadratic w^2 tokenizer/adapter compute of the text bucket.
    Zero padding WITHIN a bucket stays exact: padded token channels are
    zero, so padded adapter rows receive zero gradients and stay zero
    under AdamW (no weight decay) — each bucket's program is numerically
    equivalent to the ragged one.  Bucket membership is static, so the W
    per-bucket sub-programs are stitched at trace time and the round
    stays ONE jit dispatch; the server step runs once on the
    bucket-concatenated pooled activations and the engine returns metrics
    in canonical node order (the stable node->bucket permutation is
    engine state, invisible to callers).  ``width_bucketing=False``
    restores the legacy single-bucket pad-to-max-width layout (the
    benchmark baseline).
  * **heterogeneous node types** (corrupt / bridge / synthetic-anchor)
    are static branch masks: both data branches are computed from the
    same RNG keys and selected per node, and the bridge contrastive term
    is weighted by a 0/1 mask, so ONE compiled program serves every node
    type.
  * **round compilation boundary**: local epochs + Gram upload + LAP
    precision + consensus + precision-weighted side-car averaging +
    broadcast are one jitted call — K x E dispatches per round become 1.
    Round-state buffers (stacked trainables / opt states / keys / G_bar)
    are DONATED to the compiled round, so round N's outputs alias round
    N+1's inputs and peak round-state memory stays ~1x instead of 2x.
  * **mesh path**: with ``mesh=...`` each bucket's node axis is
    ``shard_map``-ped onto the mesh batch axes (``launch.mesh.batch_axes``;
    every bucket size must divide the shard count); the server step
    becomes psum/all_gather collectives whose payload is the protocol's
    actual uplink (Grams, precisions, shipped side-cars).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, get_config
from repro.core import aggregation as agg
from repro.core import cka as cka_mod
from repro.core import engine as engine_mod
from repro.core import lora as lora_mod
from repro.core import participation as part_mod
from repro.core import uncertainty as unc
from repro.core.participation import ParticipationPlan  # re-export
from repro.data.synthetic import SyntheticMultimodal
from repro.data.tokenizers import FrozenTokenizer, default_tokenizers
from repro.models import transformer as T
from repro.models.common import cross_entropy_loss, linear, make_linear
from repro.optim.adamw import AdamW

Array = jax.Array


@dataclass(frozen=True)
class FederationConfig:
    n_nodes: int = 4
    modalities: Tuple[str, ...] = ("image", "text", "genetics", "tabular")
    method: str = "geolora"            # geolora | geodora | fedavg_full
    aggregation: str = "precision"     # precision | uniform
    lora_rank: int = 8
    lambda_geo: float = 1.0
    rounds: int = 5
    local_steps: int = 10
    local_batch: int = 32
    lr: float = 3e-3
    n_classes: int = 8
    anchors_per_class: int = 4
    n_tokens: int = 16
    corrupt_nodes: Tuple[int, ...] = ()
    # bridge clients (paper's hybrid federation): nodes holding locally
    # PAIRED data across two modalities add an intra-node contrastive loss,
    # rigidifying the global manifold alignment.
    bridge_nodes: Tuple[int, ...] = ()
    bridge_modality: str = "text"            # second modality on bridges
    lambda_bridge: float = 0.5
    # nodes whose anchor modality is MISSING from the public set and is
    # replaced by noisy synthetic anchors (digital twins); the paper claims
    # LAP naturally downweights them via the distributional shift.
    synthetic_anchor_nodes: Tuple[int, ...] = ()
    synthetic_anchor_noise: float = 2.0
    seed: int = 0
    center_cka: bool = False
    # server-side FedOpt: momentum on the precision-weighted side-car
    # average (engine-backed ``Federation`` only).  ``None`` = off (exact
    # legacy server step); 0.0 carries the state but reduces to the plain
    # average; > 0 accumulates the round pseudo-gradient.
    server_momentum: Optional[float] = None
    # global-round LR schedule (round index -> multiplier), threaded
    # through the engine's scan carry via the optimizer's "round" counter:
    # warmup/cosine ACROSS fused round blocks without re-jitting.  ``None``
    # keeps the exact legacy optimizer state structure.
    round_lr_schedule: Optional[Callable] = None


def _stopgrad_named(tree, names=("dora_m",)):
    def walk(node, name):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name) for v in node)
        if node is None:
            return None
        return jax.lax.stop_gradient(node) if name in names else node
    return walk(tree, "")


# shipped/local split lives in repro.core.lora (shared with the engine)
_shipped_mask = lora_mod.shipped_mask


def _split_by_mask(tree, mask):
    a = jax.tree.map(lambda p, m: p if (p is not None and m) else None,
                     tree, mask, is_leaf=lambda x: x is None)
    b = jax.tree.map(lambda p, m: p if (p is not None and not m) else None,
                     tree, mask, is_leaf=lambda x: x is None)
    return a, b


def _merge_by_mask(shipped, local, mask):
    return jax.tree.map(
        lambda m, s, l: s if m else l, mask, shipped, local,
        is_leaf=lambda x: x is None)


class SequentialFederation:
    """Simulated federation (K nodes on one host), sequential reference:
    Python loop over nodes, one jit dispatch per node per local step.  The
    node-stacked single-dispatch engine is ``Federation``; this class is
    the oracle it is equivalence-tested against."""

    def __init__(self, fed: FederationConfig, model: ModelConfig = None):
        self.fed = fed
        self.cfg = model or get_config("fedmm-small")
        key = jax.random.PRNGKey(fed.seed)
        k_model, k_data, k_anchor, k_lora, k_nodes = jax.random.split(key, 5)

        # ---- substrate: task, tokenizers, anchors ----
        from repro.configs.fedmm_base import MODALITY_TOKENIZER_DIMS
        self.task = SyntheticMultimodal(n_classes=fed.n_classes,
                                        modalities=fed.modalities,
                                        seed=fed.seed)
        self.tokenizers = default_tokenizers(
            {m: MODALITY_TOKENIZER_DIMS[m] for m in fed.modalities},
            self.task.d_raw, fed.n_tokens, seed=fed.seed)
        anchors_raw = self.task.anchor_set(k_anchor, fed.anchors_per_class)
        # pre-tokenize public anchors once per modality (tokenizers frozen)
        self.anchor_tokens = {m: self.tokenizers[m](anchors_raw[m][0])
                              for m in fed.modalities}
        # synthetic (generated) anchors: same class structure, heavy noise
        self.synthetic_anchor_tokens = {}
        if fed.synthetic_anchor_nodes:
            kn = jax.random.fold_in(k_anchor, 777)
            for m, (raw, _) in anchors_raw.items():
                noisy = raw + fed.synthetic_anchor_noise * \
                    jax.random.normal(jax.random.fold_in(
                        kn, hash(m) % (2 ** 31)), raw.shape)
                self.synthetic_anchor_tokens[m] = self.tokenizers[m](noisy)

        # ---- global model (the paper's VLM-initialised homogeneous
        # transformer; random init here — protocol math is init-agnostic) ----
        params = T.init_params(k_model, self.cfg)
        if fed.method in ("geolora", "geodora"):
            spec = lora_mod.LoRASpec(rank=fed.lora_rank,
                                     dora=(fed.method == "geodora"))
            params = lora_mod.attach_lora(k_lora, params, spec)
        kh = jax.random.fold_in(k_model, 99)
        params["cls_head"] = make_linear(kh, self.cfg.d_model, fed.n_classes,
                                         jnp.float32)

        if fed.method == "fedavg_full":
            mask = jax.tree.map(lambda _: True, params)
        else:
            mask = lora_mod.trainable_mask(params)
        self.mask = mask
        trainable, self.frozen = lora_mod.partition(params, mask)

        # ---- per-node state: shared trainables + local adapter ----
        self.node_modality = [fed.modalities[i % len(fed.modalities)]
                              for i in range(fed.n_nodes)]
        self.opt = AdamW(lr=fed.lr, weight_decay=0.0, grad_clip=1.0,
                         round_schedule=fed.round_lr_schedule)
        self.nodes = []
        for i in range(fed.n_nodes):
            m = self.node_modality[i]
            ka = jax.random.fold_in(k_nodes, i)
            node_train = dict(trainable)
            node_train["adapter"] = make_linear(
                ka, self.tokenizers[m].d_out, self.cfg.d_model, jnp.float32)
            self.nodes.append({
                "trainable": node_train,
                "opt_state": self.opt.init(node_train),
                "modality": m,
                "corrupt": i in fed.corrupt_nodes,
                "bridge": i in fed.bridge_nodes,
                "key": jax.random.fold_in(k_data, i),
            })
        # bridge clients get a second local adapter for the paired modality
        for node in self.nodes:
            if node["bridge"]:
                m2 = fed.bridge_modality
                if m2 == node["modality"]:
                    m2 = next(m for m in fed.modalities
                              if m != node["modality"])
                node["modality2"] = m2
                ka2 = jax.random.fold_in(k_nodes, 1000 + self.nodes.index(node))
                node["trainable"]["adapter2"] = make_linear(
                    ka2, self.tokenizers[m2].d_out, self.cfg.d_model,
                    jnp.float32)
                node["opt_state"] = self.opt.init(node["trainable"])
        # frozen tree needs structure-matching adapter placeholders
        self.frozen = dict(self.frozen)
        self.frozen["adapter"] = {"w": None}
        self.mask = dict(self.mask)
        self.mask["adapter"] = {"w": True}
        if any(n.get("bridge") for n in self.nodes):
            self.frozen_bridge = dict(self.frozen, adapter2={"w": None})
        else:
            self.frozen_bridge = None

        self.gbar = self._initial_consensus()
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    def _pooled(self, params, tokens) -> Array:
        embeds = linear(tokens.astype(jnp.float32), params["adapter"])
        _, aux = T.forward(params, {"inputs_embeds": embeds}, self.cfg)
        return aux["pooled"]

    def _frozen_for(self, node) -> dict:
        return self.frozen_bridge if node.get("bridge") else self.frozen

    def _initial_consensus(self) -> Array:
        grams = []
        for node in self.nodes:
            params = lora_mod.combine(node["trainable"],
                                      self._frozen_for(node))
            pooled = self._pooled(params, self.anchor_tokens[node["modality"]])
            grams.append(cka_mod.cosine_gram(pooled))
        return cka_mod.consensus_gram(jnp.stack(grams))

    # ------------------------------------------------------------------
    @staticmethod
    def _contrastive(z1: Array, z2: Array, tau: float = 0.2) -> Array:
        """Intra-node InfoNCE on locally PAIRED samples (bridge clients)."""
        z1 = z1 / jnp.maximum(jnp.linalg.norm(z1, axis=-1, keepdims=True),
                              1e-8)
        z2 = z2 / jnp.maximum(jnp.linalg.norm(z2, axis=-1, keepdims=True),
                              1e-8)
        sim = (z1 @ z2.T) / tau
        labels = jnp.arange(z1.shape[0])
        return 0.5 * (cross_entropy_loss(sim, labels)
                      + cross_entropy_loss(sim.T, labels))

    @functools.partial(jax.jit, static_argnums=(0,))
    def _local_step(self, trainable, opt_state, frozen, batch_tokens, labels,
                    anchor_tokens, gbar):
        lam = self.fed.lambda_geo

        def loss_fn(train):
            params = lora_mod.combine(train, frozen)
            pooled = self._pooled(params, batch_tokens)
            logits = linear(pooled, params["cls_head"])
            task = cross_entropy_loss(logits, labels)
            # GeoDoRA: geometric loss constrains direction only
            params_geo = lora_mod.combine(_stopgrad_named(train), frozen)
            pooled_a = self._pooled(params_geo, anchor_tokens)
            geo = cka_mod.geo_alignment_loss(pooled_a, gbar,
                                             center=self.fed.center_cka)
            acc = (logits.argmax(-1) == labels).mean()
            return task + lam * geo, (task, geo, acc, pooled, pooled_a)

        grads, (task, geo, acc, pooled, pooled_a) = \
            jax.grad(loss_fn, has_aux=True)(trainable)
        new_train, new_opt = self.opt.update(grads, opt_state, trainable)
        return new_train, new_opt, {"task": task, "geo": geo, "acc": acc,
                                    "pooled": pooled, "pooled_a": pooled_a}

    @functools.partial(jax.jit, static_argnums=(0,))
    def _bridge_step(self, trainable, opt_state, frozen, batch_tokens,
                     batch_tokens2, labels, anchor_tokens, gbar):
        """Local step on a bridge client: task + geo + paired contrastive
        between the two local modalities (paper: 'bridge clients ...
        rigidify the global manifold alignment')."""
        lam, lam_b = self.fed.lambda_geo, self.fed.lambda_bridge

        def loss_fn(train):
            params = lora_mod.combine(train, frozen)
            pooled = self._pooled(params, batch_tokens)
            params2 = dict(params, adapter=params["adapter2"])
            pooled2 = self._pooled(params2, batch_tokens2)
            logits = linear(pooled, params["cls_head"])
            task = cross_entropy_loss(logits, labels)
            contrast = self._contrastive(pooled, pooled2)
            params_geo = lora_mod.combine(_stopgrad_named(train), frozen)
            pooled_a = self._pooled(params_geo, anchor_tokens)
            geo = cka_mod.geo_alignment_loss(pooled_a, gbar,
                                             center=self.fed.center_cka)
            acc = (logits.argmax(-1) == labels).mean()
            return task + lam * geo + lam_b * contrast, \
                (task, geo, acc, pooled, pooled_a)

        grads, (task, geo, acc, pooled, pooled_a) = \
            jax.grad(loss_fn, has_aux=True)(trainable)
        new_train, new_opt = self.opt.update(grads, opt_state, trainable)
        return new_train, new_opt, {"task": task, "geo": geo, "acc": acc,
                                    "pooled": pooled, "pooled_a": pooled_a}

    # ------------------------------------------------------------------
    def run_round(self, participants=None) -> dict:
        """One protocol round.  ``participants`` (an iterable of node ids)
        restricts the round to a reporting cohort: non-participants do
        NOTHING — their trainables, optimizer moments and RNG keys carry
        through untouched, they contribute nothing to the consensus Gram /
        LAP precision pool / side-car average, and they still receive the
        server broadcast at round end (next-round downlink).  ``None`` is
        the exact legacy full-participation round."""
        fed = self.fed
        active = (None if participants is None else set(participants))
        k_active = fed.n_nodes if active is None else len(active)
        if active is not None and k_active == 0:
            raise ValueError("empty participant set")
        grams, precisions, shipped_list = [], [], []
        metrics = {"task": [], "geo": [], "acc": []}
        self._last_raw_precisions = {}
        for i, node in enumerate(self.nodes):
            if active is not None and i not in active:
                continue
            if "round" in node["opt_state"]:
                node["opt_state"] = dict(
                    node["opt_state"],
                    round=node["opt_state"]["round"] + 1)
            m = node["modality"]
            anchors = (self.synthetic_anchor_tokens[m]
                       if i in fed.synthetic_anchor_nodes
                       else self.anchor_tokens[m])
            last = None
            for s in range(fed.local_steps):
                node["key"], kb = jax.random.split(node["key"])
                raw, labels = self.task.sample(kb, m, fed.local_batch,
                                               corrupt=node["corrupt"])
                tokens = self.tokenizers[m](raw)
                if node.get("bridge"):
                    # locally paired: same latent draws through modality 2
                    m2 = node["modality2"]
                    raw2, _ = self.task.sample(kb, m2, fed.local_batch)
                    tokens2 = self.tokenizers[m2](raw2)
                    node["trainable"], node["opt_state"], last = \
                        self._bridge_step(
                            node["trainable"], node["opt_state"],
                            self.frozen_bridge, tokens, tokens2, labels,
                            anchors, self.gbar)
                else:
                    node["trainable"], node["opt_state"], last = \
                        self._local_step(
                            node["trainable"], node["opt_state"],
                            self.frozen, tokens, labels, anchors, self.gbar)
            metrics["task"].append(float(last["task"]))
            metrics["geo"].append(float(last["geo"]))
            metrics["acc"].append(float(last["acc"]))
            # upload: Gram + precision + shipped side-cars
            grams.append(cka_mod.cosine_gram(last["pooled_a"]))
            u = unc.lap_uncertainty(last["pooled"], last["pooled_a"])
            precisions.append(unc.node_precision(u))
            # device array, NOT float(): materialising here would force a
            # host sync per node per round even in full-participation runs
            # (only the precision-strategy sampler ever reads these)
            self._last_raw_precisions[i] = precisions[-1]
            smask = _shipped_mask(node["trainable"])
            shipped, _ = _split_by_mask(node["trainable"], smask)
            # bridge nodes carry extra local-only keys (adapter2) that are
            # all-None in the shipped view — drop for structural uniformity
            shipped = {k: v for k, v in shipped.items()
                       if any(l is not None for l in jax.tree.leaves(
                           v, is_leaf=lambda x: x is None))}
            shipped_list.append(shipped)

        # ---- server (averages over whichever nodes reported) ----
        grams = jnp.stack(grams)
        self.gbar = cka_mod.consensus_gram(grams)
        if fed.aggregation == "precision":
            weights = unc.precision_weights(jnp.stack(precisions))
        else:
            weights = jnp.full((k_active,), 1.0 / k_active)
        avg_shipped = agg.aggregate_geolora(shipped_list, weights)
        # broadcast to EVERY node, participants or not (next-round downlink)
        for node in self.nodes:
            merged = dict(avg_shipped)
            for k in node["trainable"]:
                if k not in merged:
                    merged[k] = jax.tree.map(lambda _: None,
                                             node["trainable"][k])
            node["trainable"] = _merge_by_mask(
                merged, node["trainable"], _shipped_mask(node["trainable"]))

        off_diag = cka_mod.mean_offdiag_cka(grams, center=fed.center_cka)
        shipped_bytes = agg.comm_bytes_per_round(
            shipped_list[0], gram_side=self.gbar.shape[0])
        full_bytes = lora_mod.param_bytes(
            lora_mod.combine(self.nodes[0]["trainable"],
                             self._frozen_for(self.nodes[0])))
        rec = {
            "task_loss": sum(metrics["task"]) / k_active,
            "geo_loss": sum(metrics["geo"]) / k_active,
            "acc": sum(metrics["acc"]) / k_active,
            "cross_node_cka": float(off_diag),
            "uplink_bytes": int(shipped_bytes),
            "full_model_bytes": int(full_bytes),
        }
        if active is None:
            rec["weights"] = [float(w) for w in weights]
        else:
            # full-length weight vector, zero at non-reporting nodes, plus
            # the per-round participation log the engine also emits
            ordered = sorted(active)
            wfull = [0.0] * fed.n_nodes
            for wi, i in zip(weights, ordered):
                wfull[i] = float(wi)
            rec["weights"] = wfull
            rec["participation"] = [1.0 if i in active else 0.0
                                    for i in range(fed.n_nodes)]
            rec["cohort_size"] = k_active
        self.history.append(rec)
        return rec

    # ------------------------------------------------------------------
    # participation (sequential reference): the SAME sampler functions the
    # engine traces into its compiled round run here eagerly, over the
    # same width-bucket group layout, so the cohort sequence is identical
    # — this class is the oracle the masked/compacted engine paths are
    # equivalence-tested against.
    def _node_width(self, node) -> int:
        """Adapter width the node needs inside its bucket: its tokenizer's
        d_out, or for a bridge node the max of its two adapters' widths."""
        d = self.tokenizers[node["modality"]].d_out
        if node.get("bridge"):
            d = max(d, self.tokenizers[node["modality2"]].d_out)
        return d

    def _participation_groups(self) -> tuple:
        """Canonical node ids per width bucket — the sampler's group
        layout, mirroring the engine's default bucketed layout."""
        nodes = self.nodes
        widths = [self._node_width(n) for n in nodes]
        bucket_widths = tuple(sorted(set(widths)))
        return tuple(tuple(i for i, w in enumerate(widths) if w == wb)
                     for wb in bucket_widths)

    def _sample_participants(self, plan):
        """Advance the carried sampler state one round and return the
        participating canonical node ids."""
        groups = self._participation_groups()
        prev = getattr(self, "_seq_part", None)
        if prev is None or prev[0] != plan:
            state = part_mod.init_state(plan, self.fed.n_nodes)
        else:
            state = prev[1]
        row_masks, _, state = part_mod.sample_rows(plan, state, groups)
        self._seq_part = (plan, state)
        parts = [g[r] for g, mask in zip(groups, row_masks)
                 for r in range(len(g)) if float(mask[r]) > 0]
        return sorted(parts), groups

    def _update_seq_sampler(self, plan, groups, participants):
        """Fold this round's reported precisions into the sampler state
        (precision-proportional strategy), mirroring the engine's
        on-device ``update_state``."""
        if plan.strategy != "precision":
            return
        plan_, state = self._seq_part
        rows = [i for g in groups for i in g]         # row order
        mask = jnp.asarray([1.0 if i in participants else 0.0
                            for i in rows], jnp.float32)
        p = jnp.asarray([float(self._last_raw_precisions.get(i, 0.0))
                         for i in rows], jnp.float32)
        self._seq_part = (plan_, part_mod.update_state(plan, state, mask,
                                                       p))

    def run_rounds(self, n: int, block_size: int = 1,
                   participation=None) -> List[dict]:
        """Run ``n`` rounds.  ``block_size`` is accepted for API parity with
        the engine-backed ``Federation`` (whose blocks fuse M rounds into
        one dispatch); the sequential reference always steps per round.
        ``participation`` accepts a ``ParticipationPlan`` (or strategy
        string): cohorts are sampled eagerly with the engine's sampler."""
        plan = part_mod.normalize(participation)
        if plan is None:
            return [self.run_round() for _ in range(n)]
        if plan.strategy == "async":
            return [self._run_async_round(plan) for _ in range(n)]
        recs = []
        for _ in range(n):
            parts, groups = self._sample_participants(plan)
            recs.append(self.run_round(participants=parts))
            self._update_seq_sampler(plan, groups, set(parts))
        return recs

    # ------------------------------------------------------------------
    # async (FedBuff) reference: the SAME ``async_events`` draws from the
    # same carried key produce the identical lag/failure stream the
    # engine's compiled round consumes, and the server math calls the
    # same staleness/consensus functions — this eager loop is the oracle
    # the fused async engine path is equivalence-tested against.
    def _run_async_round(self, plan) -> dict:
        fed = self.fed
        k = fed.n_nodes
        groups = self._participation_groups()
        rows = [i for g in groups for i in g]      # canonical id per row
        prev = getattr(self, "_seq_async", None)
        if prev is None or prev[0] != plan:
            self._seq_async = (plan, part_mod.init_state(plan, k),
                               [None] * k)
        _, ctl, buf = self._seq_async
        # the server's previous broadcast value: shipped leaves are
        # identical on every node at round start (node 0 is as good as
        # any) — re-broadcast on a no-delivery round, like the engine
        smask0 = _shipped_mask(self.nodes[0]["trainable"])
        prev_shipped, _ = _split_by_mask(self.nodes[0]["trainable"],
                                         smask0)
        prev_shipped = {kk: v for kk, v in prev_shipped.items()
                        if any(l is not None for l in jax.tree.leaves(
                            v, is_leaf=lambda x: x is None))}
        prev_shipped = jax.tree.map(lambda l: l.astype(jnp.float32),
                                    prev_shipped)
        start, lag_draw, ctl = part_mod.async_events(plan, ctl)
        start_np = [float(v) for v in start]
        countdown = [int(v) for v in ctl["countdown"]]
        lag = [int(v) for v in ctl["lag"]]
        quarantined = [int(v) for v in ctl["quarantined"]]

        # starters run their local epochs; everyone else does NOTHING
        metrics = {"task": [], "geo": [], "acc": []}
        for r, i in enumerate(rows):
            if start_np[r] <= 0:
                continue
            node = self.nodes[i]
            if "round" in node["opt_state"]:
                node["opt_state"] = dict(
                    node["opt_state"],
                    round=node["opt_state"]["round"] + 1)
            m = node["modality"]
            anchors = (self.synthetic_anchor_tokens[m]
                       if i in fed.synthetic_anchor_nodes
                       else self.anchor_tokens[m])
            last = None
            for _ in range(fed.local_steps):
                node["key"], kb = jax.random.split(node["key"])
                raw, labels = self.task.sample(kb, m, fed.local_batch,
                                               corrupt=node["corrupt"])
                tokens = self.tokenizers[m](raw)
                if node.get("bridge"):
                    m2 = node["modality2"]
                    raw2, _ = self.task.sample(kb, m2, fed.local_batch)
                    tokens2 = self.tokenizers[m2](raw2)
                    node["trainable"], node["opt_state"], last = \
                        self._bridge_step(
                            node["trainable"], node["opt_state"],
                            self.frozen_bridge, tokens, tokens2, labels,
                            anchors, self.gbar)
                else:
                    node["trainable"], node["opt_state"], last = \
                        self._local_step(
                            node["trainable"], node["opt_state"],
                            self.frozen, tokens, labels, anchors,
                            self.gbar)
            metrics["task"].append(float(last["task"]))
            metrics["geo"].append(float(last["geo"]))
            metrics["acc"].append(float(last["acc"]))

            # the uplink report: shipped side-cars + Gram + precision
            gram = cka_mod.cosine_gram(last["pooled_a"])
            if fed.aggregation == "precision":
                prec = unc.node_precision(unc.lap_uncertainty(
                    last["pooled"], last["pooled_a"]))
            else:
                prec = jnp.float32(1.0)
            smask = _shipped_mask(node["trainable"])
            shipped, _ = _split_by_mask(node["trainable"], smask)
            shipped = {kk: v for kk, v in shipped.items()
                       if any(l is not None for l in jax.tree.leaves(
                           v, is_leaf=lambda x: x is None))}
            shipped = jax.tree.map(lambda l: l.astype(jnp.float32),
                                   shipped)
            if i in plan.poison_nodes:        # fault injection: uplink only
                nan = jnp.float32(jnp.nan)
                shipped = jax.tree.map(lambda l: l + nan, shipped)
                gram, prec = gram + nan, prec + nan

            # quarantine guard (same formula as the engine, eagerly)
            finite = all(bool(jnp.isfinite(l).all())
                         for l in jax.tree.leaves(shipped))
            finite = finite and bool(jnp.isfinite(gram).all()) \
                and bool(jnp.isfinite(prec).all())
            norm_sq = sum(float((l.astype(jnp.float32) ** 2).sum())
                          for l in jax.tree.leaves(shipped))
            if (not finite) or norm_sq > plan.quarantine_norm ** 2:
                quarantined[r] += 1
                continue                        # idle again; retries next
            buf[r] = {"shipped": shipped, "gram": gram,
                      "prec": jnp.float32(prec)}
            countdown[r] = int(lag_draw[r])
            lag[r] = int(lag_draw[r])

        # staleness-weighted delivery over expiring reports
        delivered = [1.0 if (c == 0 and buf[r] is not None) else 0.0
                     for r, c in enumerate(countdown)]
        base = jnp.asarray(
            [(float(buf[r]["prec"]) if buf[r] is not None else 0.0)
             if fed.aggregation == "precision" else 1.0
             for r in range(k)], jnp.float32)
        wn = unc.stale_precision_weights(
            base, jnp.asarray(lag, jnp.int32),
            jnp.asarray(delivered, jnp.float32), plan.staleness,
            plan.staleness_alpha, plan.max_staleness)
        f = unc.staleness_factor(jnp.asarray(lag, jnp.int32),
                                 plan.staleness, plan.staleness_alpha,
                                 plan.max_staleness)
        fresh = [d * (1.0 if float(f[r]) > 0 else 0.0)
                 for r, d in enumerate(delivered)]
        if float(wn.sum()) > 0:
            total = None
            for r in range(k):
                w = wn[r]
                if float(w) <= 0:
                    continue
                term = jax.tree.map(lambda l: w * l, buf[r]["shipped"])
                total = term if total is None else jax.tree.map(
                    lambda a, b_: a + b_, total, term)
        else:
            total = prev_shipped       # no deliveries: protocol idles
        for node in self.nodes:
            merged = dict(total)
            for kk in node["trainable"]:
                if kk not in merged:
                    merged[kk] = jax.tree.map(
                        lambda _: None, node["trainable"][kk])
            node["trainable"] = _merge_by_mask(
                merged, node["trainable"],
                _shipped_mask(node["trainable"]))
        if sum(fresh) > 0:
            zeros = jnp.zeros_like(self.gbar)
            grams = jnp.stack([buf[r]["gram"] if buf[r] is not None
                               else zeros for r in range(k)])
            self.gbar = cka_mod.consensus_gram(
                grams, mask=jnp.asarray(fresh, jnp.float32),
                fallback=self.gbar)
            xcka = float(cka_mod.mean_offdiag_cka(
                grams, center=fed.center_cka,
                mask=jnp.asarray(fresh, jnp.float32)))
        else:
            xcka = 0.0
        for r in range(k):
            if delivered[r] > 0:
                countdown[r] = -1
            elif countdown[r] > 0:
                countdown[r] -= 1

        self._seq_async = (plan, dict(
            ctl, countdown=jnp.asarray(countdown, jnp.int32),
            lag=jnp.asarray(lag, jnp.int32),
            quarantined=jnp.asarray(quarantined, jnp.int32)), buf)
        n_started = max(sum(1 for s in start_np if s > 0), 1)
        perm = rows
        by_node = lambda vals: [vals[perm.index(i)]
                                for i in range(k)]  # row -> canonical
        rec = {
            "task_loss": sum(metrics["task"]) / n_started,
            "geo_loss": sum(metrics["geo"]) / n_started,
            "acc": sum(metrics["acc"]) / n_started,
            "cross_node_cka": xcka,
            "weights": by_node([float(w) for w in wn]),
            "participation": by_node(start_np),
            "cohort_size": int(sum(start_np)),
            "delivered": by_node(delivered),
            "staleness": by_node([float(lag[r]) if delivered[r] > 0
                                  else -1.0 for r in range(k)]),
            "quarantined": by_node([float(q) for q in quarantined]),
            "n_delivered": float(sum(delivered)),
            "uplink_bytes": 0, "full_model_bytes": 0,
        }
        smask0 = _shipped_mask(self.nodes[0]["trainable"])
        shipped0, _ = _split_by_mask(self.nodes[0]["trainable"], smask0)
        rec["uplink_bytes"] = int(agg.comm_bytes_per_round(
            shipped0, gram_side=self.gbar.shape[0]))
        rec["full_model_bytes"] = int(lora_mod.param_bytes(
            lora_mod.combine(self.nodes[0]["trainable"],
                             self._frozen_for(self.nodes[0]))))
        self.history.append(rec)
        return rec

    def run(self, block_size: int = 1, participation=None) -> List[dict]:
        self.run_rounds(self.fed.rounds, block_size,
                        participation=participation)
        return self.history

    # ------------------------------------------------------------------
    # checkpointing: the server checkpoint is (consensus Gram + per-node
    # trainables + opt states) — the frozen base/tokenizers are rebuilt
    # deterministically from the config seed.
    def save(self, path: str) -> None:
        from repro.checkpoint import save_checkpoint
        state = {
            "gbar": self.gbar,
            "nodes": [{"trainable": n["trainable"],
                       "opt_state": n["opt_state"],
                       "key": n["key"]} for n in self.nodes],
        }
        save_checkpoint(path, state, step=len(self.history))

    def restore(self, path: str) -> int:
        from repro.checkpoint import load_checkpoint
        like = {
            "gbar": self.gbar,
            "nodes": [{"trainable": n["trainable"],
                       "opt_state": n["opt_state"],
                       "key": n["key"]} for n in self.nodes],
        }
        state, step = load_checkpoint(path, like)
        self.gbar = state["gbar"]
        for node, saved in zip(self.nodes, state["nodes"]):
            node["trainable"] = saved["trainable"]
            node["opt_state"] = saved["opt_state"]
            node["key"] = saved["key"]
        return step

    def node_params(self, i: int) -> dict:
        return lora_mod.combine(self.nodes[i]["trainable"],
                                self._frozen_for(self.nodes[i]))


class Federation(SequentialFederation):
    """Width-bucketed node-stacked federation: a thin wrapper over
    ``repro.core.engine.RoundEngine``.  One round — E vmapped local epochs
    per width bucket plus the whole server step — is a single jitted call
    with donated round-state buffers; ``run_rounds(n, block_size=M)`` fuses
    M whole rounds into one donated dispatch (lax.scan over the round body,
    on-device batch sampling from the carried RNG streams, one host sync
    per block); pass ``mesh=`` to shard each bucket's node axis over the
    mesh batch axes (see the module docstring for the architecture).
    Public API and history records match the sequential
    reference; per-node views in ``self.nodes`` are materialised lazily
    (unpadded, through the bucket permutation) from the stacked state on
    access.  Checkpoints store the BUCKETED server state and are
    engine-to-engine only — not loadable into a ``SequentialFederation``
    (whose checkpoints are per-node) nor across a different bucket layout:
    ``width_bucketing`` AND the mesh batch-slice count must match at save
    and restore (an unshardable bucketed layout falls back to the single
    padded bucket, with a warning, which changes the state structure)."""

    def __init__(self, fed: FederationConfig, model: ModelConfig = None, *,
                 mesh=None, width_bucketing: bool = True, donate: bool = True,
                 gram_backend: str = "auto"):
        super().__init__(fed, model)
        self._width_bucketing = width_bucketing
        self._donate = donate
        self._gram_backend = gram_backend
        self._build_engine(mesh)

    # self.nodes is a lazily refreshed VIEW of the stacked state: rounds
    # only mark it stale, so the hot loop never pays K x n_leaves of
    # per-node slicing unless someone actually reads the views.
    @property
    def nodes(self):
        if getattr(self, "_views_stale", False):
            self._views_stale = False
            self._refresh_node_views()
        return self._nodes

    @nodes.setter
    def nodes(self, value):
        self._nodes = value

    # ------------------------------------------------------------------
    def _bucket_layout(self, widths, mesh):
        """Per-node widths -> (bucket_widths, buckets).  With a mesh, every
        bucket's node count must divide the shard count; when the bucketed
        layout can't shard (e.g. one node per width on a multi-device
        mesh), fall back to the single pad-to-max-width bucket rather than
        reject a config the pre-bucketing engine accepted."""
        if self._width_bucketing:
            bucket_widths = tuple(sorted(set(widths)))
            buckets = [tuple(i for i, w in enumerate(widths) if w == wb)
                       for wb in bucket_widths]
        else:           # legacy layout: one bucket padded to the max width
            bucket_widths = (self._d_max,)
            buckets = [tuple(range(len(widths)))]
        if mesh is not None and len(buckets) > 1:
            from repro.launch.mesh import n_nodes as mesh_shards
            n_shards = mesh_shards(mesh)
            if any(len(m) % n_shards for m in buckets):
                import warnings
                warnings.warn(
                    f"width buckets {[len(m) for m in buckets]} do not "
                    f"divide the {n_shards} mesh batch slices; falling "
                    f"back to the single pad-to-max-width bucket "
                    f"(checkpoints from this layout require the same "
                    f"mesh shard count to restore)", stacklevel=3)
                bucket_widths = (self._d_max,)
                buckets = [tuple(range(len(widths)))]
        return bucket_widths, buckets

    def _build_engine(self, mesh) -> None:
        fed = self.fed
        nodes = self._nodes
        self._has_bridges = any(n.get("bridge") for n in nodes)
        self._d_max = max(t.d_out for t in self.tokenizers.values())
        d_model = self.cfg.d_model

        # ---- width-bucket layout (see module doc) ----
        widths = [self._node_width(n) for n in nodes]
        self._bucket_widths, buckets = self._bucket_layout(widths, mesh)
        self._buckets = tuple(buckets)
        self._node_bucket = {i: (b, r) for b, members in enumerate(buckets)
                             for r, i in enumerate(members)}

        # ---- per-bucket node-stacked state ----
        trains, opts, keyss, staticss, masks = [], [], [], [], []
        for members, wb in zip(buckets, self._bucket_widths):
            trees = []
            for i in members:
                node = nodes[i]
                t = dict(node["trainable"])
                t["adapter"] = {"w": engine_mod.pad_axis(
                    t["adapter"]["w"], wb, 0)}
                if self._has_bridges:
                    if node.get("bridge"):
                        t["adapter2"] = {"w": engine_mod.pad_axis(
                            t["adapter2"]["w"], wb, 0)}
                    else:
                        # inert slot: the masked contrastive term gives it
                        # exactly-zero grads and it is never shipped, but it
                        # must be NONZERO — a zero adapter makes pooled2 the
                        # zero vector, whose norm has a NaN gradient that
                        # poisons the whole node even under a 0.0 mask
                        t["adapter2"] = {"w": engine_mod.pad_axis(
                            make_linear(
                                jax.random.fold_in(node["key"], 4242),
                                self.tokenizers[node["modality"]].d_out,
                                d_model, jnp.float32)["w"], wb, 0)}
                trees.append(t)
            train_b = engine_mod.stack_nodes(trees)
            trains.append(train_b)
            opts.append(jax.vmap(self.opt.init)(train_b))
            keyss.append(jnp.stack([nodes[i]["key"] for i in members]))
            staticss.append(self._bucket_statics(members, wb))
            masks.append(_shipped_mask(train_b))
        self._trains = tuple(trains)
        self._opts = tuple(opts)
        self._keys = tuple(keyss)
        self._staticss = tuple(staticss)

        # comm accounting (constant across rounds; matches the reference,
        # computed from node 0's UNpadded view)
        smask0 = _shipped_mask(nodes[0]["trainable"])
        shipped0, _ = _split_by_mask(nodes[0]["trainable"], smask0)
        self._uplink_bytes = int(agg.comm_bytes_per_round(
            shipped0, gram_side=self.gbar.shape[0]))
        self._full_bytes = int(lora_mod.param_bytes(lora_mod.combine(
            nodes[0]["trainable"], self._frozen_for(nodes[0]))))

        ecfg = engine_mod.EngineConfig(
            n_nodes=fed.n_nodes, local_steps=fed.local_steps,
            aggregation=fed.aggregation, center_cka=fed.center_cka,
            bucket_sizes=tuple(len(m) for m in buckets),
            node_perm=tuple(i for members in buckets for i in members),
            donate=self._donate, gram_backend=self._gram_backend,
            server_momentum=fed.server_momentum)
        self.engine = engine_mod.RoundEngine(
            ecfg, self.opt, self._make_local_step(), tuple(masks),
            mesh=mesh)
        self._server_m = self.engine.init_server_state(self._trains)

    def _bucket_statics(self, members, wb: int) -> dict:
        """Compile-time constants for one bucket's nodes, padded to the
        bucket width ``wb``: anchor tokens, frozen tokenizer weights,
        modality maps, corrupt/bridge masks."""
        fed = self.fed
        nodes = self._nodes
        anchors, tw1, tw2, tb1, mw, mb = [], [], [], [], [], []
        for i in members:
            m = nodes[i]["modality"]
            a = (self.synthetic_anchor_tokens[m]
                 if i in fed.synthetic_anchor_nodes
                 else self.anchor_tokens[m])
            anchors.append(engine_mod.pad_axis(a, wb, -1))
            w1, b1, w2 = self.tokenizers[m].padded_weights(wb)
            tw1.append(w1), tb1.append(b1), tw2.append(w2)
            w, b = self.task.modality_map(m)
            mw.append(w), mb.append(b)
        statics = {
            "anchors": jnp.stack(anchors),
            "tok_w1": jnp.stack(tw1), "tok_b1": jnp.stack(tb1),
            "tok_w2": jnp.stack(tw2),
            "mod_w": jnp.stack(mw), "mod_b": jnp.stack(mb),
            "corrupt": jnp.array([bool(nodes[i]["corrupt"])
                                  for i in members]),
        }
        if self._has_bridges:
            b2w1, b2b1, b2w2, m2w, m2b = [], [], [], [], []
            for i in members:
                node = nodes[i]
                m2 = node.get("modality2", node["modality"])
                w1, b1, w2 = self.tokenizers[m2].padded_weights(wb)
                b2w1.append(w1), b2b1.append(b1), b2w2.append(w2)
                w, b = self.task.modality_map(m2)
                m2w.append(w), m2b.append(b)
            statics.update({
                "bridge": jnp.array([1.0 if nodes[i].get("bridge") else 0.0
                                     for i in members], jnp.float32),
                "tok2_w1": jnp.stack(b2w1), "tok2_b1": jnp.stack(b2b1),
                "tok2_w2": jnp.stack(b2w2),
                "mod2_w": jnp.stack(m2w), "mod2_b": jnp.stack(m2b),
            })
        return statics

    # ------------------------------------------------------------------
    def _make_local_step(self):
        """Per-node local step (runs under vmap over the node axis inside
        the engine's scan).  Reproduces the sequential reference exactly:
        same RNG splits, same corrupt/bridge draws, same loss terms."""
        fed, cfg, opt, dataset = self.fed, self.cfg, self.opt, self.task
        n = fed.local_batch
        has_bridges = self._has_bridges
        frozen = self.frozen_bridge if has_bridges else self.frozen
        lam, lam_b, center = fed.lambda_geo, fed.lambda_bridge, fed.center_cka

        def tokenize(raw, w1, b1, w2):
            h = jnp.einsum("nd,dlo->nlo", raw.astype(jnp.float32), w1) + b1
            return jnp.tanh(h) @ w2

        def pooled_of(params, tokens):
            embeds = linear(tokens.astype(jnp.float32), params["adapter"])
            _, aux = T.forward(params, {"inputs_embeds": embeds}, cfg)
            return aux["pooled"]

        def local_step(train, opt_state, key, gbar, st, _batch):
            key, kb = jax.random.split(key)
            # in-scan sampling: both node-type branches from the SAME keys
            # as the reference's task.sample(...), selected per node
            raw, labels, raw2 = dataset.sample_in_scan(
                kb, st["mod_w"], st["mod_b"], n, st["corrupt"],
                mod2_w=st.get("mod2_w"), mod2_b=st.get("mod2_b"))
            tokens = tokenize(raw, st["tok_w1"], st["tok_b1"], st["tok_w2"])

            def loss_fn(tr):
                params = lora_mod.combine(tr, frozen)
                pooled = pooled_of(params, tokens)
                logits = linear(pooled, params["cls_head"])
                task = cross_entropy_loss(logits, labels)
                loss = task
                if has_bridges:
                    tokens2 = tokenize(raw2, st["tok2_w1"], st["tok2_b1"],
                                       st["tok2_w2"])
                    params2 = dict(params, adapter=params["adapter2"])
                    pooled2 = pooled_of(params2, tokens2)
                    loss = loss + lam_b * st["bridge"] * \
                        SequentialFederation._contrastive(pooled, pooled2)
                params_geo = lora_mod.combine(_stopgrad_named(tr), frozen)
                pooled_a = pooled_of(params_geo, st["anchors"])
                geo = cka_mod.geo_alignment_loss(pooled_a, gbar,
                                                 center=center)
                acc = (logits.argmax(-1) == labels).mean()
                return loss + lam * geo, (task, geo, acc, pooled, pooled_a)

            grads, (task, geo, acc, pooled, pooled_a) = \
                jax.grad(loss_fn, has_aux=True)(train)
            new_train, new_opt = opt.update(grads, opt_state, train)
            return new_train, new_opt, key, {
                "task": task, "geo": geo, "acc": acc,
                "pooled": pooled, "pooled_a": pooled_a}

        return local_step

    # ------------------------------------------------------------------
    def run_round(self, participants=None) -> dict:
        """One engine round.  ``participants`` mirrors the sequential
        reference's explicit-cohort hook by running a one-shot fixed
        ``nodes`` participation plan (each DISTINCT cohort compiles its
        own round program — for per-round sampled cohorts use
        ``run_rounds(participation=...)``, which samples inside one
        compiled program)."""
        if participants is not None:
            plan = part_mod.ParticipationPlan(
                strategy="nodes", nodes=tuple(sorted(participants)))
            self._ensure_participation(plan)
            return self._run_round_part(plan)
        # round-state buffers are donated: the previous round's arrays are
        # invalidated by this call and replaced by the outputs
        (self._trains, self._opts, self._keys, self.gbar, self._server_m,
         metrics) = self.engine.round_fn(
            self._trains, self._opts, self._keys, self.gbar, self._server_m,
            self._staticss, (None,) * len(self._trains))
        rec = self._metrics_record(metrics)
        self._views_stale = True
        self.history.append(rec)
        return rec

    def _metrics_record(self, metrics, r: Optional[int] = None) -> dict:
        """One history record from engine metrics — per-round metrics when
        ``r`` is None, else round ``r`` of a block's stacked (M, ...)
        metric buffers.  Participation-aware metrics (per-node scalars are
        zero at non-reporting nodes) average over the cohort."""
        sl = (lambda x: x) if r is None else (lambda x: x[r])
        s = metrics["scalars"]
        if "participation" in metrics:
            c = max(float(sl(metrics["cohort_size"])), 1.0)
            mean = lambda x: float(jnp.sum(sl(x))) / c
        else:
            mean = lambda x: float(jnp.mean(sl(x)))
        rec = {
            "task_loss": mean(s["task"]),
            "geo_loss": mean(s["geo"]),
            "acc": mean(s["acc"]),
            "cross_node_cka": float(sl(metrics["cross_node_cka"])),
            "weights": [float(w) for w in sl(metrics["weights"])],
            "uplink_bytes": self._uplink_bytes,
            "full_model_bytes": self._full_bytes,
        }
        if "participation" in metrics:
            rec["participation"] = [float(p)
                                    for p in sl(metrics["participation"])]
            rec["cohort_size"] = int(round(float(sl(
                metrics["cohort_size"]))))
        if "delivered" in metrics:
            rec["delivered"] = [float(d) for d in sl(metrics["delivered"])]
            rec["staleness"] = [float(s) for s in sl(metrics["staleness"])]
            rec["quarantined"] = [float(q)
                                  for q in sl(metrics["quarantined"])]
            rec["n_delivered"] = float(sl(metrics["n_delivered"]))
        return rec

    def _init_part_state(self, plan):
        if plan is None:
            return None
        if plan.strategy == "async":
            return self.engine.init_async_state(
                self._trains, plan, gram_side=int(self.gbar.shape[0]))
        return part_mod.init_state(plan, self.fed.n_nodes)

    def _ensure_participation(self, plan) -> None:
        """Install ``plan`` as the active participation plan, carrying the
        sampler state across calls (and through checkpoints) when the plan
        is unchanged, re-seeding it when the plan switches.  Async plans
        additionally carry the zeroed report buffer (shaped from the
        current stacked trainables) in the state."""
        if getattr(self, "_part_plan", None) != plan \
                or not hasattr(self, "_part_state"):
            self._part_plan = plan
            self._part_state = self._init_part_state(plan)

    def _run_round_part(self, plan) -> dict:
        (self._trains, self._opts, self._keys, self.gbar, self._server_m,
         self._part_state, metrics) = self.engine.part_round_fn(plan)(
            self._trains, self._opts, self._keys, self.gbar,
            self._server_m, self._part_state, self._staticss,
            (None,) * len(self._trains))
        rec = self._metrics_record(metrics)
        self._views_stale = True
        self.history.append(rec)
        return rec

    def _make_state_tap(self, path: str):
        """Host side of the in-block checkpoint tap: receives the block
        carry at round granularity from inside the fused scan and writes
        a checkpoint structurally identical to ``save()`` (restorable by
        ``restore()``).  ``path`` may contain ``{step}``; otherwise the
        file is overwritten in place (atomic rename in save_checkpoint,
        so a crash mid-write never corrupts the previous one).  Raising
        here (disk full) is logged and dropped by the engine's tap guard
        — a failing checkpoint never kills the in-flight block."""
        from repro.checkpoint import save_checkpoint
        meta = {"server_momentum": self.fed.server_momentum,
                "n_buckets": len(self._trains),
                "round_schedule": self.fed.round_lr_schedule is not None,
                "participation": part_mod.plan_meta(
                    getattr(self, "_part_plan", None))}

        def state_tap(step: int, carry):
            if len(carry) == 6:
                tr, op, ks, gb, sm, ps = carry
            else:
                (tr, op, ks, gb, sm), ps = carry, None
            state = {"gbar": gb, "train": tr, "opt": op, "keys": ks}
            if sm is not None:
                state["server_m"] = sm
            if ps is not None:
                state["part"] = ps
            p = path.format(step=step) if "{step}" in path else path
            save_checkpoint(p, state, step=step, meta=meta)

        return state_tap

    def run_rounds(self, n: int, block_size: int = 1, tap=None,
                   participation=None, checkpoint_path: str = None,
                   checkpoint_every: int = 0) -> List[dict]:
        """Run ``n`` rounds; with ``block_size`` M > 1, rounds execute as
        fused M-round blocks (``engine.run_block``): ONE donated dispatch
        and one host sync per block instead of per round.  Dispatch is
        async — every block is enqueued before any metric is read back, so
        the device never waits on the host between blocks; history records
        materialise after the last block is in flight.  ``block_size=1`` is
        the exact legacy per-round path.  ``tap`` (block mode) streams each
        round's metrics to the host via ``io_callback`` without forcing a
        sync.

        ``participation`` (a ``ParticipationPlan`` or strategy string)
        samples a reporting cohort per round on device; the sampler state
        rides the block carry and the checkpoint.  ``None`` / ``"full"``
        is routed onto the unchanged legacy path (bit-identical).

        ``checkpoint_path`` + ``checkpoint_every`` arm the IN-BLOCK
        checkpoint tap (block mode): the full block carry streams to a
        ``restore()``-compatible checkpoint every ``checkpoint_every``
        rounds FROM INSIDE the fused scan, so killing the process
        mid-block loses < checkpoint_every rounds (< M without it losing
        the whole block).  The step recorded is the absolute round count,
        so a resumed driver knows how many rounds remain."""
        plan = part_mod.normalize(participation)
        state_tap, every = None, 0
        if checkpoint_path is not None and block_size > 1:
            if plan is not None:
                self._ensure_participation(plan)
            state_tap = self._make_state_tap(checkpoint_path)
            every = max(1, checkpoint_every)
        if plan is None:
            if block_size <= 1:
                return [self.run_round() for _ in range(n)]
            pending, done = [], 0
            while done < n:
                m = min(block_size, n - done)
                state = (self._trains, self._opts, self._keys, self.gbar,
                         self._server_m)
                (self._trains, self._opts, self._keys, self.gbar,
                 self._server_m), metrics = self.engine.run_block(
                    state, m, statics=self._staticss, tap=tap,
                    state_tap=state_tap,
                    state_tap_every=min(every, m) if state_tap else 0,
                    round_offset=len(self.history) + done)
                pending.append((m, metrics))
                done += m
        else:
            self._ensure_participation(plan)
            if block_size <= 1:
                return [self._run_round_part(plan) for _ in range(n)]
            pending, done = [], 0
            while done < n:
                m = min(block_size, n - done)
                state = (self._trains, self._opts, self._keys, self.gbar,
                         self._server_m, self._part_state)
                (self._trains, self._opts, self._keys, self.gbar,
                 self._server_m, self._part_state), metrics = \
                    self.engine.run_block(
                        state, m, statics=self._staticss, tap=tap,
                        plan=plan, state_tap=state_tap,
                        state_tap_every=min(every, m) if state_tap else 0,
                        round_offset=len(self.history) + done)
                pending.append((m, metrics))
                done += m
        self._views_stale = True
        recs = [self._metrics_record(metrics, r)
                for m, metrics in pending for r in range(m)]
        self.history.extend(recs)
        if tap is not None or state_tap is not None:
            # metric readback does not wait for the io_callback thread;
            # drain it so every round's tap has fired before returning
            jax.effects_barrier()
        return recs

    def _unpad_node_tree(self, tree: dict, node: dict) -> dict:
        """Strip the padded widths from one node's slice of a stacked tree
        (trainables or AdamW moments), restoring the reference's ragged
        per-node structure."""
        tree = dict(tree)
        d = self.tokenizers[node["modality"]].d_out
        tree["adapter"] = {"w": tree["adapter"]["w"][:d]}
        if "adapter2" in tree:
            if node.get("bridge"):
                d2 = self.tokenizers[node["modality2"]].d_out
                tree["adapter2"] = {"w": tree["adapter2"]["w"][:d2]}
            else:
                del tree["adapter2"]
        return tree

    def _refresh_node_views(self) -> None:
        """Materialise per-node (unpadded) views of the bucketed state so
        ``self.nodes`` / ``node_params`` keep the reference's shapes: node
        i lives at row r of bucket b under the stable permutation."""
        for i, node in enumerate(self._nodes):
            b, r = self._node_bucket[i]
            node["trainable"] = self._unpad_node_tree(
                jax.tree.map(lambda x: x[r], self._trains[b]), node)
            opt_i = jax.tree.map(lambda x: x[r], self._opts[b])
            node["opt_state"] = {
                "m": self._unpad_node_tree(opt_i["m"], node),
                "v": self._unpad_node_tree(opt_i["v"], node),
                "step": opt_i["step"],
            }
            if "round" in opt_i:
                node["opt_state"]["round"] = opt_i["round"]
            node["key"] = self._keys[b][r]

    # ------------------------------------------------------------------
    # checkpointing: engine checkpoints store the BUCKETED server state
    # (tuples of per-bucket stacked trees); the bucket layout is rebuilt
    # deterministically from the config, so a restore into a federation
    # with the same config and ``width_bucketing`` lands every node back
    # at its row through the same permutation
    def _ckpt_state(self) -> dict:
        state = {"gbar": self.gbar, "train": self._trains,
                 "opt": self._opts, "keys": self._keys}
        if self._server_m is not None:
            state["server_m"] = self._server_m
        if getattr(self, "_part_state", None) is not None:
            state["part"] = self._part_state
        return state

    def save(self, path: str) -> None:
        from repro.checkpoint import save_checkpoint
        # the saved state IS the engine's block carry (trains / opts / keys
        # / gbar / server-opt / participation sampler), so a save at a
        # block boundary captures everything a resumed run_block needs to
        # continue bit-identically — including the cohort sampling stream
        save_checkpoint(path, self._ckpt_state(), step=len(self.history),
                        meta={"server_momentum": self.fed.server_momentum,
                              "n_buckets": len(self._trains),
                              "round_schedule":
                                  self.fed.round_lr_schedule is not None,
                              "participation": part_mod.plan_meta(
                                  getattr(self, "_part_plan", None))})

    def restore(self, path: str) -> int:
        from repro.checkpoint import load_checkpoint, read_meta
        meta = read_meta(path)
        if meta.get("server_momentum") != self.fed.server_momentum:
            raise ValueError(
                f"checkpoint server_momentum={meta.get('server_momentum')} "
                f"does not match config {self.fed.server_momentum}; the "
                f"block carry structure differs")
        if bool(meta.get("round_schedule", False)) != \
                (self.fed.round_lr_schedule is not None):
            raise ValueError(
                f"checkpoint round_schedule="
                f"{bool(meta.get('round_schedule', False))} does not match "
                f"config round_lr_schedule="
                f"{self.fed.round_lr_schedule is not None}; the optimizer "
                f"carry structure (round counter) differs")
        plan = part_mod.plan_from_meta(meta.get("participation"))
        if plan is not None:
            # the sampler state is part of the checkpointed carry; restore
            # resumes the cohort stream without the caller re-passing the
            # plan (run_rounds with the same plan keeps the state)
            self._part_plan = plan
            self._part_state = self._init_part_state(plan)
        else:
            # a full-participation checkpoint must also restore INTO a
            # federation that previously ran with a plan: drop the stale
            # sampler state so the carry template matches the file
            self._part_plan = None
            self._part_state = None
        state, step = load_checkpoint(path, self._ckpt_state())
        self.gbar = state["gbar"]
        self._trains = state["train"]
        self._opts = state["opt"]
        self._keys = state["keys"]
        if "server_m" in state:
            self._server_m = state["server_m"]
        if "part" in state:
            self._part_state = state["part"]
        self._views_stale = True
        return step
