"""Width-bucketed node-stacked federation round engine.

The paper's protocol is embarrassingly parallel across nodes: K clients run
E local steps with zero cross-node communication, then a low-rank server
step (consensus Gram, LAP precision weights, side-car averaging) closes the
round.  This module executes that structure as ONE compiled program instead
of K x E separate jit dispatches:

  - per-node trainables / opt states / RNG keys are stacked along a leading
    node axis.  Heterogeneous tokenizer widths are grouped into W *width
    buckets* by the caller: each bucket stacks only the nodes whose
    adapters share a (padded) width, so a 192-wide tabular node never pays
    the w^2 compute of the 2048-wide text bucket.  Bucket membership is
    static, so the W per-bucket sub-programs are stitched by a plain Python
    loop at trace time — the round is still a single jit dispatch;
  - within a bucket, ``jax.vmap`` maps the caller's ``local_step`` across
    the node axis and ``jax.lax.scan`` runs the E local steps
    (zero-padding to the bucket width is exact: padded rows see zero
    inputs, receive zero gradients, and stay zero under AdamW);
  - the server step (Gram consensus + precision weights + shipped-side-car
    averaging + broadcast) runs once on the bucket-concatenated pooled
    activations, in the same program — shipped side-cars have identical
    shapes in every bucket, so the cross-bucket average is a per-bucket
    partial sum followed by a broadcast back into each bucket;
  - round-state buffers (trainables, opt states, RNG keys, consensus Gram)
    are DONATED to the compiled round (``donate_argnums``), so round N's
    outputs alias round N+1's inputs and peak round-state memory stays at
    ~1x instead of 2x at large K;
  - with ``mesh=...`` each bucket's node axis is mapped onto the mesh batch
    axes via ``shard_map`` and the server step becomes ``psum`` /
    ``all_gather`` collectives whose payload is low-rank-sized (the paper's
    communication claim, now visible as the program's only cross-slice
    traffic);
  - ``run_block(state, M)`` fuses M whole rounds into ONE dispatch: the
    round body above becomes the body of a ``jax.lax.scan`` over rounds, the
    carry is (trains, opts, keys, gbar, server-opt state) and is donated, so
    at production round rates the host pays one dispatch and zero blocking
    syncs per M rounds instead of one each per round.  Per-round batches are
    either pre-staged as an (M, E, K, ...) leaf-stacked tensor scanned over,
    or drawn on-device from the carried RNG streams (``batches=None``);
    per-round metrics accumulate into (M, ...) device buffers returned at
    block end, with an optional ``io_callback`` tap that streams each
    round's metrics to a host logger without forcing a sync.

The engine is workload-agnostic: ``local_step`` owns the loss (multimodal
classification in ``core.federation``, LM fine-tuning in ``launch.train``,
the one-local-step FedSGD form in ``launch.steps``); the engine owns
batching, the round loop, and the server math.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core import cka as cka_mod
from repro.core import uncertainty as unc

Array = jax.Array

# local_step(train, opt_state, key, gbar, statics, batch)
#   -> (train, opt_state, key, aux)
# where aux holds per-node "pooled" (B, D) and "pooled_a" (Ba, D) plus any
# scalar metrics; train/opt_state/statics/batch are the PER-NODE slices.
LocalStep = Callable[..., Tuple[Any, Any, Array, dict]]


@dataclass(frozen=True)
class EngineConfig:
    n_nodes: int
    local_steps: int
    aggregation: str = "precision"     # precision | uniform
    center_cka: bool = False
    # width buckets: per-bucket node counts (sum == n_nodes).  () means a
    # single bucket of all n_nodes (the homogeneous / legacy-padded layout).
    bucket_sizes: Tuple[int, ...] = ()
    # canonical node id of each engine row (bucket-concatenated order);
    # () means identity.  Metrics are returned in CANONICAL node order.
    node_perm: Tuple[int, ...] = ()
    # donate round-state buffers (train/opt/keys/gbar) to the compiled
    # round so outputs alias inputs (halves peak round-state memory).
    donate: bool = True
    # Gram backend for the server step: "auto" (Pallas on TPU, reference
    # elsewhere), "reference" (core.cka), or "pallas" (kernels.gram; runs
    # in interpreter mode off-TPU so it stays testable on CPU).
    gram_backend: str = "auto"
    # server-side FedOpt: momentum coefficient applied to the round's
    # pseudo-gradient (broadcast value of the previous round minus the
    # precision-weighted average) before re-broadcasting.  ``None`` disables
    # the feature entirely (exact legacy server step, no extra carried
    # state); 0.0 keeps the state but reduces to the plain average.
    server_momentum: Optional[float] = None


def pad_axis(x: Array, width: int, axis: int = -1) -> Array:
    """Zero-pad ``axis`` of ``x`` up to ``width`` (no-op when already there).
    Zero padding keeps the padded program exactly equivalent: padded input
    columns are zero, so padded weight rows get zero gradients and never
    leave zero under moment-based optimizers without weight decay."""
    n = x.shape[axis]
    if n == width:
        return x
    if n > width:
        raise ValueError(f"axis {axis} has {n} > target width {width}")
    pads = [(0, 0)] * x.ndim
    pads[axis if axis >= 0 else x.ndim + axis] = (0, width - n)
    return jnp.pad(x, pads)


def stack_nodes(trees) -> Any:
    """Stack structurally identical per-node pytrees along a new leading
    node axis (``None`` placeholder leaves pass through)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _as_buckets(x) -> tuple:
    return x if isinstance(x, tuple) else (x,)


class RoundEngine:
    """One federated round as a single compiled function.

    State layout: the round state is a TUPLE of per-bucket pytrees.  Every
    leaf of ``trains[b]`` / ``opts[b]`` carries a leading node axis of the
    bucket's size; ``keys[b]`` is (k_b, 2) uint32; ``gbar`` is the
    replicated consensus Gram shared by all buckets.  ``round_fn(trains,
    opts, keys, gbar, statics, batches)`` returns ``(trains, opts, keys,
    gbar, metrics)`` where ``metrics = {"scalars": {name: (K,)},
    "weights": (K,), "cross_node_cka": ()}`` — per-node entries in
    CANONICAL node order (the engine un-permutes the bucket layout).

    ``batches[b]`` is either ``None`` (the local step samples its own data
    from the carried RNG keys) or a pytree with leading (E, k_b, ...) axes
    scanned over the local steps.  ``statics[b]`` is a per-node constant
    pytree (leading k_b axis) vmapped alongside the state — anchor tokens,
    modality maps, corrupt/bridge masks.

    Shipped side-car leaves must have identical shapes in every bucket
    (only node-LOCAL leaves — the W_mk adapters — may differ in width),
    which is what lets the server average run across buckets.

    Single-bucket callers pass 1-tuples (a bare pytree is auto-wrapped for
    the shipped mask only; state must always be tuples).
    """

    def __init__(self, ecfg: EngineConfig, opt, local_step: LocalStep,
                 shipped_masks, *, mesh=None, jit: bool = True):
        self.ecfg = ecfg
        self.opt = opt
        self.local_step = local_step
        self.shipped_masks = _as_buckets(shipped_masks)
        self.bucket_sizes = ecfg.bucket_sizes or (ecfg.n_nodes,)
        self.n_buckets = len(self.bucket_sizes)
        if sum(self.bucket_sizes) != ecfg.n_nodes:
            raise ValueError(f"bucket_sizes {self.bucket_sizes} do not sum "
                             f"to n_nodes={ecfg.n_nodes}")
        if len(self.shipped_masks) != self.n_buckets:
            raise ValueError(f"{len(self.shipped_masks)} shipped masks for "
                             f"{self.n_buckets} buckets")
        perm = ecfg.node_perm or tuple(range(ecfg.n_nodes))
        if sorted(perm) != list(range(ecfg.n_nodes)):
            raise ValueError(f"node_perm {perm} is not a permutation")
        inv = [0] * ecfg.n_nodes
        for row, node in enumerate(perm):
            inv[node] = row
        # identity permutations skip the gather entirely
        self._inv_perm = (None if tuple(perm) == tuple(range(ecfg.n_nodes))
                          else tuple(inv))
        self.mesh = mesh
        if ecfg.gram_backend not in ("auto", "reference", "pallas"):
            raise ValueError(f"unknown gram_backend {ecfg.gram_backend!r}; "
                             f"expected auto | reference | pallas")
        self._gram_backend = ecfg.gram_backend
        if self._gram_backend == "auto":
            self._gram_backend = ("pallas" if jax.default_backend() == "tpu"
                                  else "reference")
        donate = (0, 1, 2, 3, 4) if ecfg.donate else ()
        self._block_cache = {}
        self._tap_holders = {}
        if mesh is None:
            # jit=False leaves round_fn as the plain round body, for callers
            # that inline the round into their own compilation boundary
            # (launch.steps owns jit/shardings/donation itself)
            self.round_fn = (jax.jit(self._round, donate_argnums=donate)
                             if jit else self._round)
        else:
            from repro.launch.mesh import batch_axes
            from repro.launch.mesh import n_nodes as mesh_shards
            self._axes = batch_axes(mesh)
            n_shards = mesh_shards(mesh)
            if not self._axes:
                raise ValueError("mesh has no batch axes to map nodes onto")
            for b, kb in enumerate(self.bucket_sizes):
                if kb % n_shards:
                    raise ValueError(
                        f"bucket {b} has {kb} nodes, not divisible by the "
                        f"{n_shards} mesh batch slices {self._axes}")
            self.round_fn = (jax.jit(self._round_sharded,
                                     donate_argnums=donate)
                             if jit else self._round_sharded)

    # ------------------------------------------------------------------
    def _grams_of(self, pooled_a: Array) -> Array:
        """(K, Ba, D) -> (K, Ba, Ba) anchor Grams, dispatched by backend:
        the MXU-tiled Pallas kernel on TPU (interpret mode elsewhere, so
        the dispatch stays CPU-testable), the jnp reference otherwise."""
        if self._gram_backend == "pallas":
            from repro.kernels.gram import cosine_gram_pallas
            fn = functools.partial(
                cosine_gram_pallas,
                interpret=(jax.default_backend() != "tpu"))
            return jax.vmap(fn)(pooled_a)
        return jax.vmap(cka_mod.cosine_gram)(pooled_a)

    def _unpermute(self, x: Array) -> Array:
        """Engine-row order (bucket-concatenated) -> canonical node order."""
        if self._inv_perm is None:
            return x
        return jnp.take(x, jnp.asarray(self._inv_perm), axis=0)

    # ------------------------------------------------------------------
    # server-side FedOpt (optional): momentum on the averaged side-cars
    def init_server_state(self, trains):
        """Zero FedOpt momentum tree, shaped like the shipped-leaf average
        (None at non-shipped leaves); ``None`` when the knob is off, so the
        legacy path carries no extra state."""
        if self.ecfg.server_momentum is None:
            return None
        none = lambda x: x is None
        return jax.tree.map(
            lambda l, m: (jnp.zeros(l.shape[1:], jnp.float32)
                          if (l is not None and m) else None),
            trains[0], self.shipped_masks[0], is_leaf=none)

    def _server_prev(self, trains):
        """The value the server broadcast LAST round: shipped rows are
        identical across nodes at round start, so row 0 of bucket 0 is the
        server's previous iterate (float32, None at non-shipped leaves)."""
        none = lambda x: x is None
        return jax.tree.map(
            lambda l, m: (l[0].astype(jnp.float32)
                          if (l is not None and m) else None),
            trains[0], self.shipped_masks[0], is_leaf=none)

    def _apply_server_momentum(self, prev, total, server_m):
        """FedAvgM server step: pseudo-gradient = prev - avg; momentum
        accumulates it and the server re-broadcasts prev - m.  With
        beta == 0 this reduces to broadcasting the plain average."""
        beta = float(self.ecfg.server_momentum)
        none = lambda x: x is None
        new_m = jax.tree.map(
            lambda sm, p, t: None if t is None else beta * sm + (p - t),
            server_m, prev, total, is_leaf=none)
        new_val = jax.tree.map(
            lambda p, m_: None if p is None else p - m_,
            prev, new_m, is_leaf=none)
        return new_m, new_val

    # ------------------------------------------------------------------
    def _local_epochs(self, train, opt_state, keys, gbar, statics, batches):
        """scan over E local steps of the vmapped per-node step; returns the
        advanced state plus the LAST step's aux (pooled / pooled_a /
        scalars) — what the server consumes, mirroring the sequential
        reference."""
        batch_axis = None if batches is None else 0

        def body(carry, xs):
            tr, op, ks = carry
            tr, op, ks, aux = jax.vmap(
                self.local_step, in_axes=(0, 0, 0, None, 0, batch_axis),
            )(tr, op, ks, gbar, statics, xs)
            return (tr, op, ks), aux

        (train, opt_state, keys), auxs = jax.lax.scan(
            body, (train, opt_state, keys), batches,
            length=self.ecfg.local_steps if batches is None else None)
        last = jax.tree.map(lambda a: a[-1], auxs)
        return train, opt_state, keys, last

    # ------------------------------------------------------------------
    def _round(self, trains, opts, keys, gbar, server_m, statics, batches):
        k = self.ecfg.n_nodes
        prev = None if server_m is None else self._server_prev(trains)
        trains, opts, keys = list(trains), list(opts), list(keys)
        lasts = []
        # static Python loop over buckets: W sub-vmaps, ONE compiled round
        for b in range(self.n_buckets):
            trains[b], opts[b], keys[b], last = self._local_epochs(
                trains[b], opts[b], keys[b], gbar, statics[b], batches[b])
            lasts.append(last)
        pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
        pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
        scalars = {name: jnp.concatenate([l[name] for l in lasts])
                   for name in lasts[0]}

        # ---- server (same program: no extra dispatch) ----
        grams = self._grams_of(pooled_a)
        new_gbar = cka_mod.consensus_gram(grams)
        if self.ecfg.aggregation == "precision":
            weights = unc.precision_weights(
                unc.batched_precisions(pooled, pooled_a))
        else:
            weights = jnp.full((k,), 1.0 / k, jnp.float32)
        if server_m is None:
            trains = agg.weighted_average_bucketed(
                tuple(trains), weights, self.shipped_masks,
                self.bucket_sizes)
        else:
            total = agg.bucketed_partial_sums(
                tuple(trains), weights, self.shipped_masks,
                self.bucket_sizes)
            server_m, new_val = self._apply_server_momentum(
                prev, total, server_m)
            trains = agg.broadcast_into_buckets(
                tuple(trains), self.shipped_masks, new_val)
        metrics = {
            "scalars": {name: self._unpermute(v)
                        for name, v in scalars.items()},
            "weights": self._unpermute(weights),
            "cross_node_cka": cka_mod.mean_offdiag_cka(
                grams, center=self.ecfg.center_cka),
        }
        return (tuple(trains), tuple(opts), tuple(keys), new_gbar, server_m,
                metrics)

    # ------------------------------------------------------------------
    def _round_sharded(self, trains, opts, keys, gbar, server_m, statics,
                       batches):
        """shard_map path: each bucket's node axis split over the mesh
        batch axes; the server step's cross-slice traffic is exactly the
        protocol's uplink (Grams + precisions + shipped side-cars)."""
        ax = self._axes
        k = self.ecfg.n_nodes
        node_spec = P(ax)
        batch_specs = tuple(P() if b is None else P(None, ax)
                            for b in batches)

        def inner(trains, opts, keys, gbar, server_m, statics, batches):
            prev = None if server_m is None else self._server_prev(trains)
            trains, opts, keys = list(trains), list(opts), list(keys)
            lasts = []
            for b in range(self.n_buckets):
                trains[b], opts[b], keys[b], last = self._local_epochs(
                    trains[b], opts[b], keys[b], gbar,
                    statics[b], batches[b])
                lasts.append(last)
            pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
            pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
            scalars = {name: jnp.concatenate([l[name] for l in lasts])
                       for name in lasts[0]}
            kb_loc = tuple(ks.shape[0] for ks in keys)
            k_loc = sum(kb_loc)

            grams_loc = self._grams_of(pooled_a)
            new_gbar = jax.lax.psum(grams_loc.sum(0), ax) / k
            if self.ecfg.aggregation == "precision":
                p_loc = jnp.maximum(
                    unc.batched_precisions(pooled, pooled_a), 0.0)
                w_loc = p_loc / jnp.maximum(
                    jax.lax.psum(p_loc.sum(), ax), 1e-12)
            else:
                w_loc = jnp.full((k_loc,), 1.0 / k, jnp.float32)

            # shipped average: per-bucket local partial sums -> one psum ->
            # broadcast (the unsharded server math with a psum in between)
            total = agg.bucketed_partial_sums(
                tuple(trains), w_loc, self.shipped_masks, kb_loc)
            total = jax.tree.map(
                lambda a: None if a is None else jax.lax.psum(a, ax),
                total, is_leaf=lambda x: x is None)
            if server_m is not None:
                # prev and total are replicated here, so the momentum
                # update needs no extra collective
                server_m, total = self._apply_server_momentum(
                    prev, total, server_m)
            trains = list(agg.broadcast_into_buckets(
                tuple(trains), self.shipped_masks, total))

            # gather per BUCKET (each reassembles that bucket's node order),
            # then concatenate — gathering the locally-concatenated array
            # would interleave shard-major instead of bucket-major
            gather = functools.partial(jax.lax.all_gather, axis_name=ax,
                                       axis=0, tiled=True)

            def gather_cat(v_loc):
                off, parts = 0, []
                for kb in kb_loc:
                    parts.append(gather(v_loc[off:off + kb]))
                    off += kb
                return jnp.concatenate(parts)

            grams_all = gather(grams_loc)   # order-invariant consumer
            metrics = {
                "scalars": {name: self._unpermute(gather_cat(v))
                            for name, v in scalars.items()},
                "weights": self._unpermute(gather_cat(w_loc)),
                "cross_node_cka": cka_mod.mean_offdiag_cka(
                    grams_all, center=self.ecfg.center_cka),
            }
            return (tuple(trains), tuple(opts), tuple(keys), new_gbar,
                    server_m, metrics)

        return _shard_map(
            inner, mesh=self.mesh,
            in_specs=(node_spec, node_spec, node_spec, P(), P(), node_spec,
                      batch_specs),
            out_specs=(node_spec, node_spec, node_spec, P(), P(), P()),
        )(trains, opts, keys, gbar, server_m, statics, batches)

    # ------------------------------------------------------------------
    # fused multi-round blocks: lax.scan over M whole rounds, one dispatch
    def block_fn(self, m: int, *, tap=None):
        """Compiled M-round block: ``jax.lax.scan`` over the round body with
        the (trains, opts, keys, gbar, server_m) carry DONATED, so M rounds
        cost one dispatch and zero intermediate host syncs.  ``tap`` is an
        optional host callback fired once per round (via ``io_callback``,
        ordered) with that round's metrics — an async log stream that never
        blocks the device.  Compiled functions are cached per (m, has-tap):
        the tap routes through a holder read at callback time, so passing a
        fresh closure per call swaps the target without re-tracing the
        M-round scan (the LATEST tap handles any still-in-flight blocks;
        ``jax.effects_barrier()`` drains pending callbacks before swapping
        if that matters).  Scan traces the round body once, so compile time
        is ~independent of M."""
        if m < 1:
            raise ValueError(f"block size must be >= 1, got {m}")
        cache_key = (m, tap is not None)
        if tap is not None:
            self._tap_holders.setdefault(cache_key, [None])[0] = tap
        fn = self._block_cache.get(cache_key)
        if fn is not None:
            return fn
        body_fn = self._round if self.mesh is None else self._round_sharded
        holder = self._tap_holders.get(cache_key)

        def block(trains, opts, keys, gbar, server_m, statics, batches):
            def body(carry, xs):
                tr, op, ks, gb, sm = carry
                tr, op, ks, gb, sm, metrics = body_fn(
                    tr, op, ks, gb, sm, statics, xs)
                if holder is not None:
                    from jax.experimental import io_callback
                    io_callback(lambda metr: holder[0](metr), None,
                                metrics, ordered=True)
                return (tr, op, ks, gb, sm), metrics

            # per-bucket batches carry leading (M, E, k_b, ...) axes and are
            # scanned over; None buckets sample on-device from the carried
            # RNG keys.  The stacked ys ARE the (M, ...) metric buffers.
            (trains, opts, keys, gbar, server_m), metrics = jax.lax.scan(
                body, (trains, opts, keys, gbar, server_m), batches,
                length=m)
            return trains, opts, keys, gbar, server_m, metrics

        donate = (0, 1, 2, 3, 4) if self.ecfg.donate else ()
        fn = jax.jit(block, donate_argnums=donate)
        self._block_cache[cache_key] = fn
        return fn

    def run_block(self, state, m: int, *, statics, batches=None, tap=None):
        """Run M fused rounds in ONE donated dispatch.

        ``state`` is the round carry ``(trains, opts, keys, gbar,
        server_m)``; ``batches`` is a per-bucket tuple of either ``None``
        (draw on-device from the carried RNG stream) or a pytree with
        leading ``(M, E, k_b, ...)`` axes pre-staged on device.  Returns
        ``(state, metrics)`` where every metrics leaf gained a leading M
        axis (round-major).  The call is ASYNC: nothing blocks until the
        caller materialises an output, so drivers can stage block N+1's
        batches while block N is in flight."""
        if batches is None:
            batches = (None,) * self.n_buckets
        out = self.block_fn(m, tap=tap)(*state, statics, batches)
        return out[:5], out[5]


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: jax <= 0.4.x exposes it under
    jax.experimental (with ``check_rep``); newer releases move it to
    ``jax.shard_map`` and rename/ drop that kwarg."""
    try:
        from jax.experimental.shard_map import shard_map as sm
    except ImportError:                                   # jax >= 0.7
        sm = jax.shard_map
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
