"""Node-stacked federation round engine.

The paper's protocol is embarrassingly parallel across nodes: K clients run
E local steps with zero cross-node communication, then a low-rank server
step (consensus Gram, LAP precision weights, side-car averaging) closes the
round.  This module executes that structure as ONE compiled program instead
of K x E separate jit dispatches:

  - per-node trainables / opt states / RNG keys are stacked along a leading
    node axis (heterogeneous adapters are padded to the max tokenizer width
    by the caller — zero-padding is exact: padded rows see zero inputs,
    receive zero gradients, and stay zero under AdamW);
  - ``jax.vmap`` maps the caller's ``local_step`` across the node axis;
  - ``jax.lax.scan`` runs the E local steps;
  - the server step (Gram consensus + precision weights + shipped-side-car
    averaging + broadcast) runs in the same program, so one round is a
    single ``jax.jit`` call;
  - with ``mesh=...`` the node axis is mapped onto the mesh batch axes via
    ``shard_map`` and the server step becomes ``psum``/``all_gather``
    collectives whose payload is low-rank-sized (the paper's communication
    claim, now visible as the program's only cross-slice traffic).

The engine is workload-agnostic: ``local_step`` owns the loss (multimodal
classification in ``core.federation``, LM fine-tuning in ``launch.train``);
the engine owns batching, the round loop, and the server math.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core import cka as cka_mod
from repro.core import uncertainty as unc

Array = jax.Array

# local_step(train, opt_state, key, gbar, statics, batch)
#   -> (train, opt_state, key, aux)
# where aux holds per-node "pooled" (B, D) and "pooled_a" (Ba, D) plus any
# scalar metrics; train/opt_state/statics/batch are the PER-NODE slices.
LocalStep = Callable[..., Tuple[Any, Any, Array, dict]]


@dataclass(frozen=True)
class EngineConfig:
    n_nodes: int
    local_steps: int
    aggregation: str = "precision"     # precision | uniform
    center_cka: bool = False


def pad_axis(x: Array, width: int, axis: int = -1) -> Array:
    """Zero-pad ``axis`` of ``x`` up to ``width`` (no-op when already there).
    Zero padding keeps the padded program exactly equivalent: padded input
    columns are zero, so padded weight rows get zero gradients and never
    leave zero under moment-based optimizers without weight decay."""
    n = x.shape[axis]
    if n == width:
        return x
    if n > width:
        raise ValueError(f"axis {axis} has {n} > target width {width}")
    pads = [(0, 0)] * x.ndim
    pads[axis if axis >= 0 else x.ndim + axis] = (0, width - n)
    return jnp.pad(x, pads)


def stack_nodes(trees) -> Any:
    """Stack structurally identical per-node pytrees along a new leading
    node axis (``None`` placeholder leaves pass through)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class RoundEngine:
    """One federated round as a single compiled function.

    State layout: every leaf of ``node_train`` / ``node_opt`` carries a
    leading node axis of size K; ``node_keys`` is (K, 2) uint32; ``gbar``
    is the replicated consensus Gram.  ``round_fn(train, opt, keys, gbar,
    statics, batches)`` returns ``(train, opt, keys, gbar, metrics)`` where
    ``metrics = {"scalars": {name: (K,)}, "weights": (K,),
    "cross_node_cka": ()}``.

    ``batches`` is either ``None`` (the local step samples its own data from
    the carried RNG keys) or a pytree with leading (E, K, ...) axes scanned
    over the local steps.  ``statics`` is a per-node constant pytree
    (leading K axis) vmapped alongside the state — anchor tokens, modality
    maps, corrupt/bridge masks.
    """

    def __init__(self, ecfg: EngineConfig, opt, local_step: LocalStep,
                 shipped_mask, *, mesh=None):
        self.ecfg = ecfg
        self.opt = opt
        self.local_step = local_step
        self.shipped_mask = shipped_mask
        self.mesh = mesh
        if mesh is None:
            self.round_fn = jax.jit(self._round)
        else:
            from repro.launch.mesh import batch_axes
            self._axes = batch_axes(mesh)
            n_shards = 1
            for a in self._axes:
                n_shards *= mesh.shape[a]
            if not self._axes:
                raise ValueError("mesh has no batch axes to map nodes onto")
            if ecfg.n_nodes % n_shards:
                raise ValueError(
                    f"n_nodes={ecfg.n_nodes} not divisible by the "
                    f"{n_shards} mesh batch slices {self._axes}")
            self.round_fn = jax.jit(self._round_sharded)

    # ------------------------------------------------------------------
    def _local_epochs(self, train, opt_state, keys, gbar, statics, batches):
        """scan over E local steps of the vmapped per-node step; returns the
        advanced state plus the LAST step's aux (pooled / pooled_a /
        scalars) — what the server consumes, mirroring the sequential
        reference."""
        batch_axis = None if batches is None else 0

        def body(carry, xs):
            tr, op, ks = carry
            tr, op, ks, aux = jax.vmap(
                self.local_step, in_axes=(0, 0, 0, None, 0, batch_axis),
            )(tr, op, ks, gbar, statics, xs)
            return (tr, op, ks), aux

        (train, opt_state, keys), auxs = jax.lax.scan(
            body, (train, opt_state, keys), batches,
            length=self.ecfg.local_steps if batches is None else None)
        last = jax.tree.map(lambda a: a[-1], auxs)
        return train, opt_state, keys, last

    # ------------------------------------------------------------------
    def _round(self, train, opt_state, keys, gbar, statics, batches):
        k = self.ecfg.n_nodes
        train, opt_state, keys, last = self._local_epochs(
            train, opt_state, keys, gbar, statics, batches)
        pooled = last.pop("pooled")
        pooled_a = last.pop("pooled_a")

        # ---- server (same program: no extra dispatch) ----
        grams = jax.vmap(cka_mod.cosine_gram)(pooled_a)
        new_gbar = cka_mod.consensus_gram(grams)
        if self.ecfg.aggregation == "precision":
            weights = unc.precision_weights(
                unc.batched_precisions(pooled, pooled_a))
        else:
            weights = jnp.full((k,), 1.0 / k, jnp.float32)
        train = agg.weighted_average_stacked(train, weights,
                                             self.shipped_mask)
        metrics = {
            "scalars": last,
            "weights": weights,
            "cross_node_cka": cka_mod.mean_offdiag_cka(
                grams, center=self.ecfg.center_cka),
        }
        return train, opt_state, keys, new_gbar, metrics

    # ------------------------------------------------------------------
    def _round_sharded(self, train, opt_state, keys, gbar, statics, batches):
        """shard_map path: node axis split over the mesh batch axes; the
        server step's cross-slice traffic is exactly the protocol's uplink
        (Grams + precisions + shipped side-cars)."""
        ax = self._axes
        k = self.ecfg.n_nodes
        node_spec = P(ax)
        batch_spec = P() if batches is None else P(None, ax)

        def inner(train, opt_state, keys, gbar, statics, batches):
            train, opt_state, keys, last = self._local_epochs(
                train, opt_state, keys, gbar, statics, batches)
            pooled = last.pop("pooled")
            pooled_a = last.pop("pooled_a")
            k_loc = keys.shape[0]

            grams_loc = jax.vmap(cka_mod.cosine_gram)(pooled_a)
            new_gbar = jax.lax.psum(grams_loc.sum(0), ax) / k
            if self.ecfg.aggregation == "precision":
                p_loc = jnp.maximum(
                    unc.batched_precisions(pooled, pooled_a), 0.0)
                w_loc = p_loc / jnp.maximum(
                    jax.lax.psum(p_loc.sum(), ax), 1e-12)
            else:
                w_loc = jnp.full((k_loc,), 1.0 / k, jnp.float32)

            def avg(leaf, m):
                if leaf is None or not m:
                    return leaf
                a = jnp.tensordot(w_loc.astype(jnp.float32),
                                  leaf.astype(jnp.float32), axes=1)
                a = jax.lax.psum(a, ax).astype(leaf.dtype)
                return jnp.broadcast_to(a[None], leaf.shape)

            train = jax.tree.map(avg, train, self.shipped_mask,
                                 is_leaf=lambda x: x is None)
            gather = functools.partial(jax.lax.all_gather, axis_name=ax,
                                       axis=0, tiled=True)
            grams_all = gather(grams_loc)
            metrics = {
                "scalars": jax.tree.map(gather, last),
                "weights": gather(w_loc),
                "cross_node_cka": cka_mod.mean_offdiag_cka(
                    grams_all, center=self.ecfg.center_cka),
            }
            return train, opt_state, keys, new_gbar, metrics

        return _shard_map(
            inner, mesh=self.mesh,
            in_specs=(node_spec, node_spec, node_spec, P(), node_spec,
                      batch_spec),
            out_specs=(node_spec, node_spec, node_spec, P(), P()),
        )(train, opt_state, keys, gbar, statics, batches)


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: jax <= 0.4.x exposes it under
    jax.experimental (with ``check_rep``); newer releases move it to
    ``jax.shard_map`` and rename/ drop that kwarg."""
    try:
        from jax.experimental.shard_map import shard_map as sm
    except ImportError:                                   # jax >= 0.7
        sm = jax.shard_map
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
