"""Width-bucketed node-stacked federation round engine.

The paper's protocol is embarrassingly parallel across nodes: K clients run
E local steps with zero cross-node communication, then a low-rank server
step (consensus Gram, LAP precision weights, side-car averaging) closes the
round.  This module executes that structure as ONE compiled program instead
of K x E separate jit dispatches:

  - per-node trainables / opt states / RNG keys are stacked along a leading
    node axis.  Heterogeneous tokenizer widths are grouped into W *width
    buckets* by the caller: each bucket stacks only the nodes whose
    adapters share a (padded) width, so a 192-wide tabular node never pays
    the w^2 compute of the 2048-wide text bucket.  Bucket membership is
    static, so the W per-bucket sub-programs are stitched by a plain Python
    loop at trace time — the round is still a single jit dispatch;
  - within a bucket, ``jax.vmap`` maps the caller's ``local_step`` across
    the node axis and ``jax.lax.scan`` runs the E local steps
    (zero-padding to the bucket width is exact: padded rows see zero
    inputs, receive zero gradients, and stay zero under AdamW);
  - the server step (Gram consensus + precision weights + shipped-side-car
    averaging + broadcast) runs once on the bucket-concatenated pooled
    activations, in the same program — shipped side-cars have identical
    shapes in every bucket, so the cross-bucket average is a per-bucket
    partial sum followed by a broadcast back into each bucket;
  - round-state buffers (trainables, opt states, RNG keys, consensus Gram)
    are DONATED to the compiled round (``donate_argnums``), so round N's
    outputs alias round N+1's inputs and peak round-state memory stays at
    ~1x instead of 2x at large K;
  - with ``mesh=...`` each bucket's node axis is mapped onto the mesh batch
    axes via ``shard_map`` and the server step becomes ``psum`` /
    ``all_gather`` collectives whose payload is low-rank-sized (the paper's
    communication claim, now visible as the program's only cross-slice
    traffic);
  - ``run_block(state, M)`` fuses M whole rounds into ONE dispatch: the
    round body above becomes the body of a ``jax.lax.scan`` over rounds, the
    carry is (trains, opts, keys, gbar, server-opt state) and is donated, so
    at production round rates the host pays one dispatch and zero blocking
    syncs per M rounds instead of one each per round.  Per-round batches are
    either pre-staged as an (M, E, K, ...) leaf-stacked tensor scanned over,
    or drawn on-device from the carried RNG streams (``batches=None``);
    per-round metrics accumulate into (M, ...) device buffers returned at
    block end, with an optional ``io_callback`` tap that streams each
    round's metrics to a host logger without forcing a sync (ordered on a
    single host; unordered per-host on a mesh, each payload carrying its
    round index, so pods are never serialised by the log stream);
  - a ``ParticipationPlan`` (``repro.core.participation``) threads sampled
    cohorts and straggler masks through all of the above: the cohort is
    drawn ON DEVICE from a carried sampler state (part of the donated
    round/block carry, so it composes with the fused scan and
    checkpoints), static-cohort strategies GATHER the cohort rows into
    compact per-bucket states so local-epoch compute scales with the
    cohort size C instead of K, the dropout/straggler path masks state
    updates so non-reporters carry through untouched, and the server step
    (consensus Gram, LAP precisions, side-car average, FedAvgM) runs over
    exactly the reporting cohort.  ``participation=full`` never touches
    any of this — it routes to the unchanged legacy program.

The engine is workload-agnostic: ``local_step`` owns the loss (multimodal
classification in ``core.federation``, LM fine-tuning in ``launch.train``,
the one-local-step FedSGD form in ``launch.steps``); the engine owns
batching, the round loop, and the server math.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core import cka as cka_mod
from repro.core import participation as part_mod
from repro.core import uncertainty as unc

Array = jax.Array


def auto_block_size(dispatch_s: float, round_s: float, *,
                    target: float = 0.05, cap: int = 64) -> int:
    """Pick the fused-block size M from measured host dispatch overhead:
    the per-round host work under M-round blocks is ~``dispatch_s / M``,
    so the smallest M with ``dispatch_s / M < target * round_s`` keeps
    host work under ``target`` (default 5%) of round time.  Clamped to
    [1, cap]; degenerate measurements (zero/negative round time) take the
    cap.  Drivers measure once at startup (``--block-size auto``)."""
    if round_s <= 0 or dispatch_s <= 0:
        return cap if round_s <= 0 else 1
    import math
    m = math.ceil(dispatch_s / (target * round_s))
    return max(1, min(int(m), cap))

# local_step(train, opt_state, key, gbar, statics, batch)
#   -> (train, opt_state, key, aux)
# where aux holds per-node "pooled" (B, D) and "pooled_a" (Ba, D) plus any
# scalar metrics; train/opt_state/statics/batch are the PER-NODE slices.
LocalStep = Callable[..., Tuple[Any, Any, Array, dict]]


@dataclass(frozen=True)
class EngineConfig:
    n_nodes: int
    local_steps: int
    aggregation: str = "precision"     # precision | uniform
    center_cka: bool = False
    # width buckets: per-bucket node counts (sum == n_nodes).  () means a
    # single bucket of all n_nodes (the homogeneous / legacy-padded layout).
    bucket_sizes: Tuple[int, ...] = ()
    # canonical node id of each engine row (bucket-concatenated order);
    # () means identity.  Metrics are returned in CANONICAL node order.
    node_perm: Tuple[int, ...] = ()
    # donate round-state buffers (train/opt/keys/gbar) to the compiled
    # round so outputs alias inputs (halves peak round-state memory).
    donate: bool = True
    # Gram backend for the server step: "auto" (Pallas on TPU, reference
    # elsewhere), "reference" (core.cka), or "pallas" (kernels.gram; runs
    # in interpreter mode off-TPU so it stays testable on CPU).
    gram_backend: str = "auto"
    # server-side FedOpt: momentum coefficient applied to the round's
    # pseudo-gradient (broadcast value of the previous round minus the
    # precision-weighted average) before re-broadcasting.  ``None`` disables
    # the feature entirely (exact legacy server step, no extra carried
    # state); 0.0 keeps the state but reduces to the plain average.
    server_momentum: Optional[float] = None


def pad_axis(x: Array, width: int, axis: int = -1) -> Array:
    """Zero-pad ``axis`` of ``x`` up to ``width`` (no-op when already there).
    Zero padding keeps the padded program exactly equivalent: padded input
    columns are zero, so padded weight rows get zero gradients and never
    leave zero under moment-based optimizers without weight decay."""
    n = x.shape[axis]
    if n == width:
        return x
    if n > width:
        raise ValueError(f"axis {axis} has {n} > target width {width}")
    pads = [(0, 0)] * x.ndim
    pads[axis if axis >= 0 else x.ndim + axis] = (0, width - n)
    return jnp.pad(x, pads)


def stack_nodes(trees) -> Any:
    """Stack structurally identical per-node pytrees along a new leading
    node axis (``None`` placeholder leaves pass through)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def masked_select(mask: Array, new_tree, old_tree):
    """Per-row state selection under a participation mask: rows with
    ``mask > 0`` take the advanced value, other rows carry the old one
    through untouched.  Works on whole pytrees (or bare arrays) whose
    leaves lead with the node-row axis — what makes a straggler's round a
    no-op on every piece of its state."""
    def sel(new, old):
        m = mask.reshape((mask.shape[0],) + (1,) * (new.ndim - 1)) > 0
        return jnp.where(m, new, old)
    return jax.tree.map(sel, new_tree, old_tree)


def _as_buckets(x) -> tuple:
    return x if isinstance(x, tuple) else (x,)


def _safe_tap(fn, *args):
    """Host side of every engine ``io_callback`` tap: an exception in the
    user's callback (disk-full during an in-block checkpoint, a logger
    bug) is LOGGED AND DROPPED instead of propagating into the runtime
    and killing the in-flight block — taps are observability, never
    control flow."""
    try:
        fn(*args)
    except Exception:
        import logging
        logging.getLogger("repro.engine").exception(
            "engine tap callback raised; payload dropped")


class RoundEngine:
    """One federated round as a single compiled function.

    State layout: the round state is a TUPLE of per-bucket pytrees.  Every
    leaf of ``trains[b]`` / ``opts[b]`` carries a leading node axis of the
    bucket's size; ``keys[b]`` is (k_b, 2) uint32; ``gbar`` is the
    replicated consensus Gram shared by all buckets.  ``round_fn(trains,
    opts, keys, gbar, statics, batches)`` returns ``(trains, opts, keys,
    gbar, metrics)`` where ``metrics = {"scalars": {name: (K,)},
    "weights": (K,), "cross_node_cka": ()}`` — per-node entries in
    CANONICAL node order (the engine un-permutes the bucket layout).

    ``batches[b]`` is either ``None`` (the local step samples its own data
    from the carried RNG keys) or a pytree with leading (E, k_b, ...) axes
    scanned over the local steps.  ``statics[b]`` is a per-node constant
    pytree (leading k_b axis) vmapped alongside the state — anchor tokens,
    modality maps, corrupt/bridge masks.

    Shipped side-car leaves must have identical shapes in every bucket
    (only node-LOCAL leaves — the W_mk adapters — may differ in width),
    which is what lets the server average run across buckets.

    Single-bucket callers pass 1-tuples (a bare pytree is auto-wrapped for
    the shipped mask only; state must always be tuples).
    """

    def __init__(self, ecfg: EngineConfig, opt, local_step: LocalStep,
                 shipped_masks, *, mesh=None, jit: bool = True):
        self.ecfg = ecfg
        self.opt = opt
        self.local_step = local_step
        self.shipped_masks = _as_buckets(shipped_masks)
        self.bucket_sizes = ecfg.bucket_sizes or (ecfg.n_nodes,)
        self.n_buckets = len(self.bucket_sizes)
        if sum(self.bucket_sizes) != ecfg.n_nodes:
            raise ValueError(f"bucket_sizes {self.bucket_sizes} do not sum "
                             f"to n_nodes={ecfg.n_nodes}")
        if len(self.shipped_masks) != self.n_buckets:
            raise ValueError(f"{len(self.shipped_masks)} shipped masks for "
                             f"{self.n_buckets} buckets")
        perm = ecfg.node_perm or tuple(range(ecfg.n_nodes))
        if sorted(perm) != list(range(ecfg.n_nodes)):
            raise ValueError(f"node_perm {perm} is not a permutation")
        inv = [0] * ecfg.n_nodes
        for row, node in enumerate(perm):
            inv[node] = row
        # identity permutations skip the gather entirely
        self._inv_perm = (None if tuple(perm) == tuple(range(ecfg.n_nodes))
                          else tuple(inv))
        self.mesh = mesh
        if ecfg.gram_backend not in ("auto", "reference", "pallas"):
            raise ValueError(f"unknown gram_backend {ecfg.gram_backend!r}; "
                             f"expected auto | reference | pallas")
        self._gram_backend = ecfg.gram_backend
        if self._gram_backend == "auto":
            self._gram_backend = ("pallas" if jax.default_backend() == "tpu"
                                  else "reference")
        # canonical node ids per bucket (row order) and the row offset of
        # each bucket — the participation sampler's group layout
        groups, offs, off = [], [], 0
        for kb in self.bucket_sizes:
            groups.append(tuple(perm[off:off + kb]))
            offs.append(off)
            off += kb
        self._groups = tuple(groups)
        self._bucket_offsets = tuple(offs)
        donate = (0, 1, 2, 3, 4) if ecfg.donate else ()
        self._block_cache = {}
        self._part_cache = {}
        self._tap_holders = {}
        if mesh is None:
            # jit=False leaves round_fn as the plain round body, for callers
            # that inline the round into their own compilation boundary
            # (launch.steps owns jit/shardings/donation itself)
            self.round_fn = (jax.jit(self._round, donate_argnums=donate)
                             if jit else self._round)
        else:
            from repro.launch.mesh import batch_axes
            from repro.launch.mesh import n_nodes as mesh_shards
            self._axes = batch_axes(mesh)
            n_shards = mesh_shards(mesh)
            if not self._axes:
                raise ValueError("mesh has no batch axes to map nodes onto")
            for b, kb in enumerate(self.bucket_sizes):
                if kb % n_shards:
                    raise ValueError(
                        f"bucket {b} has {kb} nodes, not divisible by the "
                        f"{n_shards} mesh batch slices {self._axes}")
            self.round_fn = (jax.jit(self._round_sharded,
                                     donate_argnums=donate)
                             if jit else self._round_sharded)

    # ------------------------------------------------------------------
    def _grams_of(self, pooled_a: Array) -> Array:
        """(K, Ba, D) -> (K, Ba, Ba) anchor Grams, dispatched by backend:
        the MXU-tiled Pallas kernel on TPU (interpret mode elsewhere, so
        the dispatch stays CPU-testable), the jnp reference otherwise."""
        if self._gram_backend == "pallas":
            from repro.kernels.gram import cosine_gram_pallas
            fn = functools.partial(
                cosine_gram_pallas,
                interpret=(jax.default_backend() != "tpu"))
            return jax.vmap(fn)(pooled_a)
        return jax.vmap(cka_mod.cosine_gram)(pooled_a)

    def _unpermute(self, x: Array) -> Array:
        """Engine-row order (bucket-concatenated) -> canonical node order."""
        if self._inv_perm is None:
            return x
        return jnp.take(x, jnp.asarray(self._inv_perm), axis=0)

    # ------------------------------------------------------------------
    # server-side FedOpt (optional): momentum on the averaged side-cars
    def init_server_state(self, trains):
        """Zero FedOpt momentum tree, shaped like the shipped-leaf average
        (None at non-shipped leaves); ``None`` when the knob is off, so the
        legacy path carries no extra state."""
        if self.ecfg.server_momentum is None:
            return None
        none = lambda x: x is None
        return jax.tree.map(
            lambda l, m: (jnp.zeros(l.shape[1:], jnp.float32)
                          if (l is not None and m) else None),
            trains[0], self.shipped_masks[0], is_leaf=none)

    def _server_prev(self, trains):
        """The value the server broadcast LAST round: shipped rows are
        identical across nodes at round start, so row 0 of bucket 0 is the
        server's previous iterate (float32, None at non-shipped leaves)."""
        none = lambda x: x is None
        return jax.tree.map(
            lambda l, m: (l[0].astype(jnp.float32)
                          if (l is not None and m) else None),
            trains[0], self.shipped_masks[0], is_leaf=none)

    def _apply_server_momentum(self, prev, total, server_m):
        """FedAvgM server step: pseudo-gradient = prev - avg; momentum
        accumulates it and the server re-broadcasts prev - m.  With
        beta == 0 this reduces to broadcasting the plain average."""
        beta = float(self.ecfg.server_momentum)
        none = lambda x: x is None
        new_m = jax.tree.map(
            lambda sm, p, t: None if t is None else beta * sm + (p - t),
            server_m, prev, total, is_leaf=none)
        new_val = jax.tree.map(
            lambda p, m_: None if p is None else p - m_,
            prev, new_m, is_leaf=none)
        return new_m, new_val

    # ------------------------------------------------------------------
    def _local_epochs(self, train, opt_state, keys, gbar, statics, batches):
        """scan over E local steps of the vmapped per-node step; returns the
        advanced state plus the LAST step's aux (pooled / pooled_a /
        scalars) — what the server consumes, mirroring the sequential
        reference.  When the optimizer carries a global-round counter
        (``AdamW.round_schedule``), it is bumped here — once per round,
        only for the nodes whose epochs actually run, so participation
        masking/compaction skips non-reporting nodes' counters too."""
        if isinstance(opt_state, dict) and "round" in opt_state:
            opt_state = dict(opt_state, round=opt_state["round"] + 1)
        batch_axis = None if batches is None else 0

        def body(carry, xs):
            tr, op, ks = carry
            tr, op, ks, aux = jax.vmap(
                self.local_step, in_axes=(0, 0, 0, None, 0, batch_axis),
            )(tr, op, ks, gbar, statics, xs)
            return (tr, op, ks), aux

        (train, opt_state, keys), auxs = jax.lax.scan(
            body, (train, opt_state, keys), batches,
            length=self.ecfg.local_steps if batches is None else None)
        last = jax.tree.map(lambda a: a[-1], auxs)
        return train, opt_state, keys, last

    # ------------------------------------------------------------------
    def _round(self, trains, opts, keys, gbar, server_m, statics, batches):
        k = self.ecfg.n_nodes
        prev = None if server_m is None else self._server_prev(trains)
        trains, opts, keys = list(trains), list(opts), list(keys)
        lasts = []
        # static Python loop over buckets: W sub-vmaps, ONE compiled round
        for b in range(self.n_buckets):
            trains[b], opts[b], keys[b], last = self._local_epochs(
                trains[b], opts[b], keys[b], gbar, statics[b], batches[b])
            lasts.append(last)
        pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
        pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
        scalars = {name: jnp.concatenate([l[name] for l in lasts])
                   for name in lasts[0]}

        # ---- server (same program: no extra dispatch) ----
        grams = self._grams_of(pooled_a)
        new_gbar = cka_mod.consensus_gram(grams)
        if self.ecfg.aggregation == "precision":
            weights = unc.precision_weights(
                unc.batched_precisions(pooled, pooled_a))
        else:
            weights = jnp.full((k,), 1.0 / k, jnp.float32)
        if server_m is None:
            trains = agg.weighted_average_bucketed(
                tuple(trains), weights, self.shipped_masks,
                self.bucket_sizes)
        else:
            total = agg.bucketed_partial_sums(
                tuple(trains), weights, self.shipped_masks,
                self.bucket_sizes)
            server_m, new_val = self._apply_server_momentum(
                prev, total, server_m)
            trains = agg.broadcast_into_buckets(
                tuple(trains), self.shipped_masks, new_val)
        metrics = {
            "scalars": {name: self._unpermute(v)
                        for name, v in scalars.items()},
            "weights": self._unpermute(weights),
            "cross_node_cka": cka_mod.mean_offdiag_cka(
                grams, center=self.ecfg.center_cka),
        }
        return (tuple(trains), tuple(opts), tuple(keys), new_gbar, server_m,
                metrics)

    # ------------------------------------------------------------------
    # participation-aware round body (sampled cohorts / straggler masks).
    # Kept SEPARATE from ``_round`` so the full-participation path stays
    # byte-for-byte the pre-participation program (``participation=full``
    # is routed to ``round_fn`` and never traces this).
    def _round_part(self, plan, trains, opts, keys, gbar, server_m,
                    part_state, statics, batches):
        """One round under a ``ParticipationPlan``: the sampler draws this
        round's cohort from the carried ``part_state``, local epochs run
        only for (gather-compact) or are only KEPT for (masked) the
        reporting rows, and the whole server step — consensus Gram, LAP
        precisions, side-car average, FedAvgM — runs over the cohort.
        Non-reporting rows carry every piece of state (trainables, opt
        moments, RNG keys, round counters) through untouched, then receive
        the server broadcast like every other row."""
        k = self.ecfg.n_nodes
        prev = None if server_m is None else self._server_prev(trains)
        row_masks, cohort_rows, part_state = part_mod.sample_rows(
            plan, part_state, self._groups)
        compact = (plan.compact and part_mod.static_cohort(plan)
                   and cohort_rows is not None)
        trains, opts, keys = list(trains), list(opts), list(keys)
        offs = self._bucket_offsets

        if compact:
            # gather the cohort rows into compact (c_b, ...) states: local
            # epochs cost compute proportional to the cohort size C, not K
            comp_trains, comp_sizes, comp_masks = [], [], []
            lasts, rows_global = [], []
            for b in range(self.n_buckets):
                idx = cohort_rows[b]
                if int(idx.shape[0]) == 0:     # statically empty bucket
                    continue
                gat = lambda x: jnp.take(x, idx, axis=0)
                tr_c = jax.tree.map(gat, trains[b])
                op_c = jax.tree.map(gat, opts[b])
                ke_c = jnp.take(keys[b], idx, axis=0)
                st_c = (None if statics[b] is None
                        else jax.tree.map(gat, statics[b]))
                bt_c = (None if batches[b] is None
                        else jax.tree.map(
                            lambda x: jnp.take(x, idx, axis=1), batches[b]))
                tr_c, op_c, ke_c, last = self._local_epochs(
                    tr_c, op_c, ke_c, gbar, st_c, bt_c)
                # scatter the advanced cohort back; other rows untouched
                trains[b] = jax.tree.map(
                    lambda f, p: f.at[idx].set(p), trains[b], tr_c)
                opts[b] = jax.tree.map(
                    lambda f, p: f.at[idx].set(p), opts[b], op_c)
                keys[b] = keys[b].at[idx].set(ke_c)
                comp_trains.append(tr_c)
                comp_sizes.append(int(idx.shape[0]))
                comp_masks.append(self.shipped_masks[b])
                lasts.append(last)
                rows_global.append(offs[b] + idx)
            pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
            pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
            rows_cat = jnp.concatenate(rows_global)          # (C,) row ids
            c = int(rows_cat.shape[0])
            mask_rows = jnp.concatenate(row_masks)

            # ---- server over the cohort (same program) ----
            grams = self._grams_of(pooled_a)
            new_gbar = cka_mod.consensus_gram(grams)         # C rows only
            p_c = None
            if (self.ecfg.aggregation == "precision"
                    or plan.strategy == "precision"):
                p_c = unc.batched_precisions(pooled, pooled_a)
            if self.ecfg.aggregation == "precision":
                w_c = unc.precision_weights(p_c)
            else:
                w_c = jnp.full((c,), 1.0 / c, jnp.float32)
            total = agg.bucketed_partial_sums(
                tuple(comp_trains), w_c, tuple(comp_masks),
                tuple(comp_sizes))
            if server_m is not None:
                server_m, total = self._apply_server_momentum(
                    prev, total, server_m)
            trains = list(agg.broadcast_into_buckets(
                tuple(trains), self.shipped_masks, total))
            scatter = lambda v: jnp.zeros((k,), jnp.float32).at[
                rows_cat].set(v.astype(jnp.float32))
            scalars = {name: scatter(jnp.concatenate([l[name]
                                                      for l in lasts]))
                       for name in lasts[0]}
            weights_rows = scatter(w_c)
            xcka = cka_mod.mean_offdiag_cka(grams,
                                            center=self.ecfg.center_cka)
            if p_c is not None:
                part_state = part_mod.update_state(
                    plan, part_state, mask_rows, scatter(p_c))
        else:
            # masked path (dropout / opted-out compaction): every row
            # computes, only reporting rows' state advances — the update
            # selection is what makes a straggler's round a no-op
            lasts = []
            for b in range(self.n_buckets):
                tr2, op2, ke2, last = self._local_epochs(
                    trains[b], opts[b], keys[b], gbar, statics[b],
                    batches[b])
                mb = row_masks[b]
                trains[b] = masked_select(mb, tr2, trains[b])
                opts[b] = masked_select(mb, op2, opts[b])
                keys[b] = masked_select(mb, ke2, keys[b])
                lasts.append(last)
            pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
            pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
            mask_rows = jnp.concatenate(row_masks)

            grams = self._grams_of(pooled_a)
            new_gbar = cka_mod.consensus_gram(grams, mask=mask_rows)
            p_rows = None
            if (self.ecfg.aggregation == "precision"
                    or plan.strategy == "precision"):
                p_rows = unc.batched_precisions(pooled, pooled_a)
            if self.ecfg.aggregation == "precision":
                weights_rows = unc.masked_precision_weights(p_rows,
                                                            mask_rows)
            else:
                weights_rows = mask_rows / jnp.maximum(mask_rows.sum(),
                                                       1.0)
            if server_m is None:
                trains = list(agg.weighted_average_bucketed(
                    tuple(trains), weights_rows, self.shipped_masks,
                    self.bucket_sizes, part_mask=mask_rows))
            else:
                total = agg.bucketed_partial_sums(
                    tuple(trains), weights_rows, self.shipped_masks,
                    self.bucket_sizes)
                server_m, total = self._apply_server_momentum(
                    prev, total, server_m)
                trains = list(agg.broadcast_into_buckets(
                    tuple(trains), self.shipped_masks, total))
            scalars = {name: jnp.concatenate([l[name] for l in lasts])
                       * mask_rows for name in lasts[0]}
            xcka = cka_mod.mean_offdiag_cka(
                grams, center=self.ecfg.center_cka, mask=mask_rows)
            if p_rows is not None:
                part_state = part_mod.update_state(
                    plan, part_state, mask_rows, p_rows)

        metrics = {
            "scalars": {name: self._unpermute(v)
                        for name, v in scalars.items()},
            "weights": self._unpermute(weights_rows),
            "cross_node_cka": xcka,
            "participation": self._unpermute(mask_rows),
            "cohort_size": mask_rows.sum(),
        }
        return (tuple(trains), tuple(opts), tuple(keys), new_gbar,
                server_m, part_state, metrics)

    # ------------------------------------------------------------------
    # async (FedBuff-style) round body: buffered reports, staleness-
    # weighted precision averaging, quarantine guard.
    def _shipped_rows(self, trains):
        """(K,)-row stack of the SHIPPED side-car leaves across buckets
        (float32, ``None`` at non-shipped leaves): the payload layout of
        the async report buffer.  Shipped shapes are identical in every
        bucket, so the per-bucket node stacks concatenate along rows."""
        none = lambda x: x is None
        parts = [jax.tree.map(
            lambda l, m_: (l.astype(jnp.float32)
                           if (l is not None and m_) else None),
            tree, mask, is_leaf=none)
            for tree, mask in zip(trains, self.shipped_masks)]
        return jax.tree.map(
            lambda *ls: (None if ls[0] is None else jnp.concatenate(ls)),
            *parts, is_leaf=none)

    def init_async_state(self, trains, plan, gram_side: int):
        """Initial carried async state for ``plan``: the participation CTL
        arrays (RNG key, offline/countdown/lag/quarantined) plus the
        zeroed REPORT BUFFER — per-node shipped side-cars, anchor Gram
        panels, LAP precisions — shaped from ``trains``.  Rides the
        donated round/block carry and the checkpoint like every other
        piece of round state, so fused blocks and kill-and-resume compose
        with the async stream bit-identically."""
        plan = part_mod.normalize(plan)
        if plan is None or plan.strategy != "async":
            raise ValueError("init_async_state needs an async plan")
        k = self.ecfg.n_nodes
        none = lambda x: x is None
        buf = {
            "shipped": jax.tree.map(
                lambda l: None if l is None else jnp.zeros_like(l),
                self._shipped_rows(trains), is_leaf=none),
            "gram": jnp.zeros((k, gram_side, gram_side), jnp.float32),
            "prec": jnp.zeros((k,), jnp.float32),
        }
        return {"ctl": part_mod.init_state(plan, k), "buf": buf}

    def _async_server(self, plan, trains, start, lag_draw, shipped, grams,
                      prec, buf, ctl, gbar, prev, server_m):
        """The async server step on FULL (K,)-row report arrays: fault
        injection, the on-device quarantine guard, the buffer write, the
        staleness-weighted delivery average, and the broadcast.  Shared
        by the single-host and shard_map round bodies — the sharded path
        gathers its per-shard reports into replicated full arrays first,
        so the server math (and therefore the oracle equivalence) is
        identical on both.

        A round with no deliveries (or all deliveries staled out) keeps
        the previous broadcast value, consensus Gram and FedAvgM momentum
        — the protocol idles rather than collapsing toward zero."""
        k = self.ecfg.n_nodes
        none = lambda x: x is None

        # fault injection: poison_nodes' uplink reports (NEVER their local
        # state) are corrupted to NaN — the guard below must catch them
        rows = [i for g in self._groups for i in g]
        if plan.poison_nodes:
            pm = jnp.asarray([1.0 if i in plan.poison_nodes else 0.0
                              for i in rows], jnp.float32)
            nanify = lambda l: l + jnp.where(
                pm.reshape((k,) + (1,) * (l.ndim - 1)) > 0,
                jnp.float32(jnp.nan), jnp.float32(0.0))
            shipped = jax.tree.map(
                lambda l: None if l is None else nanify(l),
                shipped, is_leaf=none)
            grams, prec = nanify(grams), nanify(prec)

        # quarantine guard, ON DEVICE, before anything enters the buffer:
        # non-finite anywhere in the report, or an exploded side-car norm
        finite = jnp.ones((k,), bool)
        norm_sq = jnp.zeros((k,), jnp.float32)
        for leaf in jax.tree.leaves(shipped):
            flat = leaf.reshape(k, -1)
            finite &= jnp.isfinite(flat).all(axis=1)
            norm_sq += (flat.astype(jnp.float32) ** 2).sum(axis=1)
        finite &= jnp.isfinite(grams.reshape(k, -1)).all(axis=1)
        finite &= jnp.isfinite(prec.reshape(k, -1)).all(axis=1)
        qn = jnp.float32(plan.quarantine_norm)
        bad = ((~finite) | (norm_sq > qn * qn)).astype(jnp.float32)
        ok = start * (1.0 - bad)
        ctl = dict(ctl, quarantined=ctl["quarantined"]
                   + (start * bad).astype(jnp.int32))

        # buffer write at the ACCEPTED rows only (a rejected reporter
        # stays idle and retries next round; its old buffer slot is inert
        # because its countdown was never armed)
        sel = lambda new, old: jnp.where(
            ok.reshape((k,) + (1,) * (new.ndim - 1)) > 0, new, old)
        buf = {
            "shipped": jax.tree.map(
                lambda n, o: None if n is None else sel(n, o),
                shipped, buf["shipped"], is_leaf=none),
            "gram": sel(grams.astype(jnp.float32), buf["gram"]),
            "prec": sel(prec.astype(jnp.float32), buf["prec"]),
        }
        countdown = jnp.where(ok > 0, lag_draw, ctl["countdown"])
        lag = jnp.where(ok > 0, lag_draw, ctl["lag"])

        # delivery: reports whose lag expires THIS round, weighted by
        # precision * staleness factor and normalised over the deliveries
        delivered = (countdown == 0).astype(jnp.float32)
        f = unc.staleness_factor(lag, plan.staleness,
                                 plan.staleness_alpha, plan.max_staleness)
        fresh = delivered * (f > 0.0).astype(jnp.float32)
        base = (buf["prec"] if self.ecfg.aggregation == "precision"
                else jnp.ones((k,), jnp.float32))
        wn = unc.stale_precision_weights(
            base, lag, delivered, plan.staleness, plan.staleness_alpha,
            plan.max_staleness)
        any_del = wn.sum() > 0.0
        total = agg.weighted_average_reports(buf["shipped"], wn)
        pick = lambda t, p_: jnp.where(any_del, t, p_)
        if server_m is None:
            new_val = jax.tree.map(pick, total, prev)
        else:
            m2, v2 = self._apply_server_momentum(prev, total, server_m)
            server_m = jax.tree.map(pick, m2, server_m)
            new_val = jax.tree.map(pick, v2, prev)
        trains = list(agg.broadcast_into_buckets(
            tuple(trains), self.shipped_masks, new_val))
        new_gbar = cka_mod.consensus_gram(buf["gram"], mask=fresh,
                                          fallback=gbar)
        countdown = jnp.where(delivered > 0, jnp.int32(-1),
                              jnp.where(countdown > 0, countdown - 1,
                                        countdown))
        ctl = dict(ctl, countdown=countdown, lag=lag)
        server_metrics = {
            "weights": wn,
            "delivered": delivered,
            "staleness": jnp.where(delivered > 0, lag,
                                   jnp.int32(-1)).astype(jnp.float32),
            "quarantined": ctl["quarantined"].astype(jnp.float32),
            "n_delivered": delivered.sum(),
            "cross_node_cka": cka_mod.mean_offdiag_cka(
                buf["gram"], center=self.ecfg.center_cka, mask=fresh),
        }
        return trains, new_gbar, server_m, {"ctl": ctl, "buf": buf}, \
            server_metrics

    def _round_async(self, plan, trains, opts, keys, gbar, server_m,
                     part_state, statics, batches):
        """One async round: the carried lag-and-failure simulator decides
        which idle nodes START local work this round; starters' state
        advances (masked path — non-starters carry through untouched) and
        their reports enter the carried buffer through the quarantine
        guard with a drawn delivery lag; the server aggregates exactly
        the reports whose lag expires this round, staleness-weighted."""
        k = self.ecfg.n_nodes
        prev = self._server_prev(trains)
        ctl, buf = part_state["ctl"], part_state["buf"]
        start, lag_draw, ctl = part_mod.async_events(plan, ctl)
        trains, opts, keys = list(trains), list(opts), list(keys)
        lasts, off = [], 0
        for b in range(self.n_buckets):
            kb = self.bucket_sizes[b]
            mb = start[off:off + kb]
            off += kb
            tr2, op2, ke2, last = self._local_epochs(
                trains[b], opts[b], keys[b], gbar, statics[b], batches[b])
            trains[b] = masked_select(mb, tr2, trains[b])
            opts[b] = masked_select(mb, op2, opts[b])
            keys[b] = masked_select(mb, ke2, keys[b])
            lasts.append(last)
        pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
        pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
        scalars = {name: jnp.concatenate([l[name] for l in lasts]) * start
                   for name in lasts[0]}
        grams = self._grams_of(pooled_a)
        if self.ecfg.aggregation == "precision":
            prec = unc.batched_precisions(pooled, pooled_a)
        else:
            prec = jnp.ones((k,), jnp.float32)
        shipped = self._shipped_rows(trains)
        trains, new_gbar, server_m, part_state, srv = self._async_server(
            plan, trains, start, lag_draw, shipped, grams, prec, buf,
            ctl, gbar, prev, server_m)
        metrics = {
            "scalars": {name: self._unpermute(v)
                        for name, v in scalars.items()},
            "weights": self._unpermute(srv["weights"]),
            "cross_node_cka": srv["cross_node_cka"],
            "participation": self._unpermute(start),
            "cohort_size": start.sum(),
            "delivered": self._unpermute(srv["delivered"]),
            "staleness": self._unpermute(srv["staleness"]),
            "quarantined": self._unpermute(srv["quarantined"]),
            "n_delivered": srv["n_delivered"],
        }
        return (tuple(trains), tuple(opts), tuple(keys), new_gbar,
                server_m, part_state, metrics)

    def _round_sharded_async(self, plan, trains, opts, keys, gbar,
                             server_m, part_state, statics, batches):
        """Async on the shard_map path.  The CTL arrays and the report
        buffer are REPLICATED (every shard draws the identical event
        stream from the shared key and runs the identical full-K server
        step — replication is maintained because the math is
        deterministic); only the local epochs and per-node report
        computation are sharded, then per-bucket all_gathers reassemble
        the full (K, ...) report arrays.  Buffer replication costs
        side-car-sized memory per shard — acceptable because only
        SHIPPED (low-rank) leaves are buffered."""
        ax = self._axes
        mesh_shape = dict(self.mesh.shape)
        node_spec = P(ax)
        batch_specs = tuple(P() if b is None else P(None, ax)
                            for b in batches)

        def inner(trains, opts, keys, gbar, server_m, part_state, statics,
                  batches):
            k = self.ecfg.n_nodes
            prev = self._server_prev(trains)
            ctl, buf = part_state["ctl"], part_state["buf"]
            start, lag_draw, ctl = part_mod.async_events(plan, ctl)
            shard = jnp.zeros((), jnp.int32)
            for a in ax:
                shard = shard * mesh_shape[a] + jax.lax.axis_index(a)
            trains, opts, keys = list(trains), list(opts), list(keys)
            lasts, off = [], 0
            for b in range(self.n_buckets):
                kb = self.bucket_sizes[b]
                kb_l = keys[b].shape[0]
                sb = start[off:off + kb]
                off += kb
                mb = jax.lax.dynamic_slice(sb, (shard * kb_l,), (kb_l,))
                tr2, op2, ke2, last = self._local_epochs(
                    trains[b], opts[b], keys[b], gbar, statics[b],
                    batches[b])
                trains[b] = masked_select(mb, tr2, trains[b])
                opts[b] = masked_select(mb, op2, opts[b])
                keys[b] = masked_select(mb, ke2, keys[b])
                lasts.append(last)
            pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
            pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
            kb_loc = tuple(ks.shape[0] for ks in keys)
            k_loc = sum(kb_loc)

            grams_loc = self._grams_of(pooled_a)
            if self.ecfg.aggregation == "precision":
                prec_loc = unc.batched_precisions(pooled, pooled_a)
            else:
                prec_loc = jnp.ones((k_loc,), jnp.float32)
            shipped_loc = self._shipped_rows(trains)

            gather = functools.partial(jax.lax.all_gather, axis_name=ax,
                                       axis=0, tiled=True)

            def gather_cat(v_loc):
                off2, parts = 0, []
                for kbl in kb_loc:
                    parts.append(gather(v_loc[off2:off2 + kbl]))
                    off2 += kbl
                return jnp.concatenate(parts)

            none = lambda x: x is None
            shipped = jax.tree.map(
                lambda l: None if l is None else gather_cat(l),
                shipped_loc, is_leaf=none)
            grams = gather_cat(grams_loc)
            prec = gather_cat(prec_loc)
            scalars = {name: gather_cat(jnp.concatenate(
                [l[name] for l in lasts])) * start for name in lasts[0]}

            trains, new_gbar, server_m, part_state, srv = \
                self._async_server(plan, trains, start, lag_draw, shipped,
                                   grams, prec, buf, ctl, gbar, prev,
                                   server_m)
            metrics = {
                "scalars": {name: self._unpermute(v)
                            for name, v in scalars.items()},
                "weights": self._unpermute(srv["weights"]),
                "cross_node_cka": srv["cross_node_cka"],
                "participation": self._unpermute(start),
                "cohort_size": start.sum(),
                "delivered": self._unpermute(srv["delivered"]),
                "staleness": self._unpermute(srv["staleness"]),
                "quarantined": self._unpermute(srv["quarantined"]),
                "n_delivered": srv["n_delivered"],
            }
            return (tuple(trains), tuple(opts), tuple(keys), new_gbar,
                    server_m, part_state, metrics)

        return _shard_map(
            inner, mesh=self.mesh,
            in_specs=(node_spec, node_spec, node_spec, P(), P(), P(),
                      node_spec, batch_specs),
            out_specs=(node_spec, node_spec, node_spec, P(), P(), P(),
                       P()),
        )(trains, opts, keys, gbar, server_m, part_state, statics, batches)

    # ------------------------------------------------------------------
    def _round_sharded(self, trains, opts, keys, gbar, server_m, statics,
                       batches):
        """shard_map path: each bucket's node axis split over the mesh
        batch axes; the server step's cross-slice traffic is exactly the
        protocol's uplink (Grams + precisions + shipped side-cars)."""
        ax = self._axes
        k = self.ecfg.n_nodes
        node_spec = P(ax)
        batch_specs = tuple(P() if b is None else P(None, ax)
                            for b in batches)

        def inner(trains, opts, keys, gbar, server_m, statics, batches):
            prev = None if server_m is None else self._server_prev(trains)
            trains, opts, keys = list(trains), list(opts), list(keys)
            lasts = []
            for b in range(self.n_buckets):
                trains[b], opts[b], keys[b], last = self._local_epochs(
                    trains[b], opts[b], keys[b], gbar,
                    statics[b], batches[b])
                lasts.append(last)
            pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
            pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
            scalars = {name: jnp.concatenate([l[name] for l in lasts])
                       for name in lasts[0]}
            kb_loc = tuple(ks.shape[0] for ks in keys)
            k_loc = sum(kb_loc)

            grams_loc = self._grams_of(pooled_a)
            new_gbar = jax.lax.psum(grams_loc.sum(0), ax) / k
            if self.ecfg.aggregation == "precision":
                p_loc = jnp.maximum(
                    unc.batched_precisions(pooled, pooled_a), 0.0)
                w_loc = p_loc / jnp.maximum(
                    jax.lax.psum(p_loc.sum(), ax), 1e-12)
            else:
                w_loc = jnp.full((k_loc,), 1.0 / k, jnp.float32)

            # shipped average: per-bucket local partial sums -> one psum ->
            # broadcast (the unsharded server math with a psum in between)
            total = agg.bucketed_partial_sums(
                tuple(trains), w_loc, self.shipped_masks, kb_loc)
            total = jax.tree.map(
                lambda a: None if a is None else jax.lax.psum(a, ax),
                total, is_leaf=lambda x: x is None)
            if server_m is not None:
                # prev and total are replicated here, so the momentum
                # update needs no extra collective
                server_m, total = self._apply_server_momentum(
                    prev, total, server_m)
            trains = list(agg.broadcast_into_buckets(
                tuple(trains), self.shipped_masks, total))

            # gather per BUCKET (each reassembles that bucket's node order),
            # then concatenate — gathering the locally-concatenated array
            # would interleave shard-major instead of bucket-major
            gather = functools.partial(jax.lax.all_gather, axis_name=ax,
                                       axis=0, tiled=True)

            def gather_cat(v_loc):
                off, parts = 0, []
                for kb in kb_loc:
                    parts.append(gather(v_loc[off:off + kb]))
                    off += kb
                return jnp.concatenate(parts)

            grams_all = gather(grams_loc)   # order-invariant consumer
            metrics = {
                "scalars": {name: self._unpermute(gather_cat(v))
                            for name, v in scalars.items()},
                "weights": self._unpermute(gather_cat(w_loc)),
                "cross_node_cka": cka_mod.mean_offdiag_cka(
                    grams_all, center=self.ecfg.center_cka),
            }
            return (tuple(trains), tuple(opts), tuple(keys), new_gbar,
                    server_m, metrics)

        return _shard_map(
            inner, mesh=self.mesh,
            in_specs=(node_spec, node_spec, node_spec, P(), P(), node_spec,
                      batch_specs),
            out_specs=(node_spec, node_spec, node_spec, P(), P(), P()),
        )(trains, opts, keys, gbar, server_m, statics, batches)

    def _round_sharded_part(self, plan, trains, opts, keys, gbar, server_m,
                            part_state, statics, batches):
        """Participation on the shard_map path.  The sampler state is
        REPLICATED, so every shard draws the identical full-federation
        cohort and slices out its own rows (the shard's linearised index
        over the mesh batch axes); execution is always the masked path —
        cross-shard gather-compaction would need a resharding collective
        that costs more than the masked compute it saves.  The server
        collectives are the legacy psums with mask-aware normalisation."""
        ax = self._axes
        mesh_shape = dict(self.mesh.shape)
        node_spec = P(ax)
        batch_specs = tuple(P() if b is None else P(None, ax)
                            for b in batches)

        def inner(trains, opts, keys, gbar, server_m, part_state, statics,
                  batches):
            prev = None if server_m is None else self._server_prev(trains)
            row_masks, _, part_state = part_mod.sample_rows(
                plan, part_state, self._groups)
            mask_full = jnp.concatenate(row_masks)       # replicated (K,)
            shard = jnp.zeros((), jnp.int32)
            for a in ax:
                shard = shard * mesh_shape[a] + jax.lax.axis_index(a)
            trains, opts, keys = list(trains), list(opts), list(keys)
            lasts, masks_loc = [], []
            for b in range(self.n_buckets):
                kb_loc = keys[b].shape[0]
                mb = jax.lax.dynamic_slice(row_masks[b],
                                           (shard * kb_loc,), (kb_loc,))
                tr2, op2, ke2, last = self._local_epochs(
                    trains[b], opts[b], keys[b], gbar, statics[b],
                    batches[b])
                trains[b] = masked_select(mb, tr2, trains[b])
                opts[b] = masked_select(mb, op2, opts[b])
                keys[b] = masked_select(mb, ke2, keys[b])
                lasts.append(last)
                masks_loc.append(mb)
            pooled = jnp.concatenate([l.pop("pooled") for l in lasts])
            pooled_a = jnp.concatenate([l.pop("pooled_a") for l in lasts])
            scalars = {name: jnp.concatenate([l[name] for l in lasts])
                       for name in lasts[0]}
            m_loc = jnp.concatenate(masks_loc)
            kb_loc = tuple(ks.shape[0] for ks in keys)

            grams_loc = self._grams_of(pooled_a)
            g_num = jax.lax.psum(
                (m_loc[:, None, None] * grams_loc).sum(0), ax)
            new_gbar = g_num / jnp.maximum(jax.lax.psum(m_loc.sum(), ax),
                                           1.0)
            p_loc = None
            if (self.ecfg.aggregation == "precision"
                    or plan.strategy == "precision"):
                p_loc = jnp.maximum(
                    unc.batched_precisions(pooled, pooled_a), 0.0)
            if self.ecfg.aggregation == "precision":
                w_loc = m_loc * p_loc / jnp.maximum(
                    jax.lax.psum((m_loc * p_loc).sum(), ax), 1e-12)
            else:
                w_loc = m_loc / jnp.maximum(
                    jax.lax.psum(m_loc.sum(), ax), 1.0)

            total = agg.bucketed_partial_sums(
                tuple(trains), w_loc, self.shipped_masks, kb_loc)
            total = jax.tree.map(
                lambda a_: None if a_ is None else jax.lax.psum(a_, ax),
                total, is_leaf=lambda x: x is None)
            if server_m is not None:
                server_m, total = self._apply_server_momentum(
                    prev, total, server_m)
            trains = list(agg.broadcast_into_buckets(
                tuple(trains), self.shipped_masks, total))

            gather = functools.partial(jax.lax.all_gather, axis_name=ax,
                                       axis=0, tiled=True)

            def gather_cat(v_loc):
                off, parts = 0, []
                for kb in kb_loc:
                    parts.append(gather(v_loc[off:off + kb]))
                    off += kb
                return jnp.concatenate(parts)

            # per-bucket gather keeps grams aligned with the bucket-major
            # replicated mask (a plain shard-major gather would mispair)
            grams_all = gather_cat(grams_loc)
            if p_loc is not None:
                part_state = part_mod.update_state(
                    plan, part_state, mask_full, gather_cat(p_loc))
            metrics = {
                "scalars": {name: self._unpermute(gather_cat(v) * mask_full)
                            for name, v in scalars.items()},
                "weights": self._unpermute(gather_cat(w_loc)),
                "cross_node_cka": cka_mod.mean_offdiag_cka(
                    grams_all, center=self.ecfg.center_cka,
                    mask=mask_full),
                "participation": self._unpermute(mask_full),
                "cohort_size": mask_full.sum(),
            }
            return (tuple(trains), tuple(opts), tuple(keys), new_gbar,
                    server_m, part_state, metrics)

        return _shard_map(
            inner, mesh=self.mesh,
            in_specs=(node_spec, node_spec, node_spec, P(), P(), P(),
                      node_spec, batch_specs),
            out_specs=(node_spec, node_spec, node_spec, P(), P(), P(),
                       P()),
        )(trains, opts, keys, gbar, server_m, part_state, statics, batches)

    # ------------------------------------------------------------------
    def part_round_fn(self, plan):
        """Compiled participation-aware round for ``plan`` (cached per
        plan; plans are frozen/hashable).  Signature adds the sampler
        state: ``(trains, opts, keys, gbar, server_m, part_state, statics,
        batches) -> (..., part_state, metrics)``; the round-state buffers
        INCLUDING the sampler state are donated."""
        plan = part_mod.normalize(plan)
        if plan is None:
            raise ValueError("full participation is the legacy round_fn")
        fn = self._part_cache.get(plan)
        if fn is not None:
            return fn
        if plan.strategy == "async":
            body = (self._round_async if self.mesh is None
                    else self._round_sharded_async)
        else:
            body = (self._round_part if self.mesh is None
                    else self._round_sharded_part)
        donate = (0, 1, 2, 3, 4, 5) if self.ecfg.donate else ()
        fn = jax.jit(functools.partial(body, plan), donate_argnums=donate)
        self._part_cache[plan] = fn
        return fn

    # ------------------------------------------------------------------
    # fused multi-round blocks: lax.scan over M whole rounds, one dispatch
    def block_fn(self, m: int, *, tap=None, plan=None, state_tap=None,
                 state_tap_every: int = 0):
        """Compiled M-round block: ``jax.lax.scan`` over the round body with
        the (trains, opts, keys, gbar, server_m) carry DONATED, so M rounds
        cost one dispatch and zero intermediate host syncs.  ``tap`` is an
        optional host callback fired once per round (via ``io_callback``,
        ordered) with that round's metrics — an async log stream that never
        blocks the device.  Compiled functions are cached per
        (m, has-tap, plan, has-state-tap, every): the taps route through
        holders read at callback time, so passing a fresh closure per call
        swaps the target without re-tracing the M-round scan (the LATEST
        tap handles any still-in-flight blocks; ``jax.effects_barrier()``
        drains pending callbacks before swapping if that matters).  Scan
        traces the round body once, so compile time is ~independent of M.

        ``state_tap`` is the IN-BLOCK CHECKPOINT tap: a host callback
        ``state_tap(abs_round, carry)`` fired every ``state_tap_every``
        rounds FROM INSIDE the scan (unordered ``io_callback`` under a
        ``lax.cond``), so preemption during a long fused block loses
        < state_tap_every rounds instead of the whole block.  When armed,
        the compiled block takes one extra TRAILING scalar argument — the
        absolute round offset of the block — so in-flight blocks carry
        their own base round and the holder-swap pattern stays valid.
        Host-side exceptions in either tap are logged and dropped
        (``_safe_tap``) — a full disk never kills the in-flight block."""
        if m < 1:
            raise ValueError(f"block size must be >= 1, got {m}")
        if state_tap is not None and not 1 <= state_tap_every <= m:
            raise ValueError(f"state_tap_every {state_tap_every} outside "
                             f"[1, {m}]")
        plan = part_mod.normalize(plan)
        cache_key = (m, tap is not None, plan, state_tap is not None,
                     state_tap_every if state_tap is not None else 0)
        if tap is not None:
            self._tap_holders.setdefault(cache_key, [None])[0] = tap
        if state_tap is not None:
            self._tap_holders.setdefault(("state",) + cache_key,
                                         [None])[0] = state_tap
        fn = self._block_cache.get(cache_key)
        if fn is not None:
            return fn
        holder = self._tap_holders.get(cache_key)
        sholder = self._tap_holders.get(("state",) + cache_key)
        # the tap is ORDERED on a single host (log lines arrive in round
        # order) but UNORDERED on a mesh, so per-host callback delivery
        # never serialises the pods (ROADMAP item); each payload carries
        # its ``round_in_block`` index so consumers can reassemble order.
        ordered_tap = self.mesh is None

        def fire_tap(metrics, ridx):
            if holder is None:
                return
            from jax.experimental import io_callback
            io_callback(
                lambda i, metr: _safe_tap(
                    holder[0], dict(metr, round_in_block=int(i))),
                None, ridx, metrics, ordered=ordered_tap)

        def fire_state_tap(carry, ridx, r0):
            # unordered io_callback is legal under lax.cond (ordered is
            # not), and checkpoint writes are self-describing (each
            # payload carries its absolute round), so ordering is free
            if sholder is None:
                return
            from jax.experimental import io_callback
            every = state_tap_every

            def fire(c):
                io_callback(
                    lambda r_, c_: _safe_tap(sholder[0], int(r_), c_),
                    None, r0 + ridx + 1, c, ordered=False)
                return jnp.int32(0)

            jax.lax.cond((ridx + 1) % every == 0,
                         fire, lambda c: jnp.int32(0), carry)

        if plan is None:
            body_fn = (self._round if self.mesh is None
                       else self._round_sharded)

            def block(trains, opts, keys, gbar, server_m, statics,
                      batches, *r0):
                def body(carry, xs):
                    ridx, bt = xs
                    tr, op, ks, gb, sm = carry
                    tr, op, ks, gb, sm, metrics = body_fn(
                        tr, op, ks, gb, sm, statics, bt)
                    fire_tap(metrics, ridx)
                    fire_state_tap((tr, op, ks, gb, sm), ridx,
                                   r0[0] if r0 else 0)
                    return (tr, op, ks, gb, sm), metrics

                # per-bucket batches carry leading (M, E, k_b, ...) axes
                # and are scanned over; None buckets sample on-device from
                # the carried RNG keys.  The stacked ys ARE the (M, ...)
                # metric buffers.
                (trains, opts, keys, gbar, server_m), metrics = \
                    jax.lax.scan(body, (trains, opts, keys, gbar,
                                        server_m),
                                 (jnp.arange(m), batches), length=m)
                return trains, opts, keys, gbar, server_m, metrics

            donate = (0, 1, 2, 3, 4) if self.ecfg.donate else ()
        else:
            if plan.strategy == "async":
                part_body = (self._round_async if self.mesh is None
                             else self._round_sharded_async)
            else:
                part_body = (self._round_part if self.mesh is None
                             else self._round_sharded_part)

            def block(trains, opts, keys, gbar, server_m, part_state,
                      statics, batches, *r0):
                def body(carry, xs):
                    ridx, bt = xs
                    tr, op, ks, gb, sm, ps = carry
                    tr, op, ks, gb, sm, ps, metrics = part_body(
                        plan, tr, op, ks, gb, sm, ps, statics, bt)
                    fire_tap(metrics, ridx)
                    fire_state_tap((tr, op, ks, gb, sm, ps), ridx,
                                   r0[0] if r0 else 0)
                    return (tr, op, ks, gb, sm, ps), metrics

                (trains, opts, keys, gbar, server_m, part_state), \
                    metrics = jax.lax.scan(
                        body, (trains, opts, keys, gbar, server_m,
                               part_state),
                        (jnp.arange(m), batches), length=m)
                return (trains, opts, keys, gbar, server_m, part_state,
                        metrics)

            donate = (0, 1, 2, 3, 4, 5) if self.ecfg.donate else ()
        fn = jax.jit(block, donate_argnums=donate)
        self._block_cache[cache_key] = fn
        return fn

    def run_block(self, state, m: int, *, statics, batches=None, tap=None,
                  plan=None, state_tap=None, state_tap_every: int = 0,
                  round_offset: int = 0):
        """Run M fused rounds in ONE donated dispatch.

        ``state`` is the round carry ``(trains, opts, keys, gbar,
        server_m)`` — plus the participation sampler state as a sixth
        element when ``plan`` is given; ``batches`` is a per-bucket tuple
        of either ``None`` (draw on-device from the carried RNG stream) or
        a pytree with leading ``(M, E, k_b, ...)`` axes pre-staged on
        device.  Returns ``(state, metrics)`` where every metrics leaf
        gained a leading M axis (round-major).  The call is ASYNC: nothing
        blocks until the caller materialises an output, so drivers can
        stage block N+1's batches while block N is in flight.

        ``state_tap``/``state_tap_every``/``round_offset`` arm the
        in-block checkpoint tap (see ``block_fn``): ``state_tap(abs_round,
        carry)`` fires from inside the scan every ``state_tap_every``
        rounds, with ``abs_round = round_offset + rounds completed``."""
        if batches is None:
            batches = (None,) * self.n_buckets
        plan = part_mod.normalize(plan)
        n_state = 5 if plan is None else 6
        fn = self.block_fn(m, tap=tap, plan=plan, state_tap=state_tap,
                           state_tap_every=state_tap_every)
        args = (*state, statics, batches)
        if state_tap is not None:
            args = args + (jnp.int32(round_offset),)
        out = fn(*args)
        return out[:n_state], out[n_state]


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: jax <= 0.4.x exposes it under
    jax.experimental (with ``check_rep``); newer releases move it to
    ``jax.shard_map`` and rename/ drop that kwarg."""
    try:
        from jax.experimental.shard_map import shard_map as sm
    except ImportError:                                   # jax >= 0.7
        sm = jax.shard_map
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
