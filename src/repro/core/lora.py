"""GeoLoRA / GeoDoRA parameter management (paper Eqs. 3-5).

GeoLoRA: every targeted linear gets side-cars ``lora_A`` (Gaussian, FROZEN,
identical on every node — eliminating the B@A rotation ambiguity that makes
naive federated LoRA averaging inconsistent, paper Eq. 4) and ``lora_B``
(zero-init, trainable, the only thing communicated).

GeoDoRA additionally adds ``dora_m`` (column-magnitude vector): direction is
aggregated and geometrically aligned, magnitude absorbs local domain shift
(paper Eq. 5).

This module is backbone-agnostic: it works by traversing any model pytree
and augmenting linears by name, so the paper's technique attaches to every
assigned architecture.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import add_dora, add_lora

# Default targets: attention projections (present in every attention arch) +
# the mixer in/out projections of SSM / RG-LRU blocks.
DEFAULT_TARGETS = ("wq", "wk", "wv", "wo", "in_proj", "out_proj",
                   "in_rec", "out", "wq_b", "w_dkv", "w_ukv")
# Node-local trainable leaf names / subtree names (paper: W_mk adapters stay
# local; lora_B and dora_m are trained and shipped).
TRAINABLE_LEAVES = ("lora_B", "dora_m")
LOCAL_SUBTREES = ("adapter", "adapter2", "enc_adapter")
SHARED_SUBTREES = ("cls_head",)          # small heads trained + averaged


@dataclass(frozen=True)
class LoRASpec:
    rank: int = 16
    targets: Tuple[str, ...] = DEFAULT_TARGETS
    dora: bool = False
    scale: float = 1.0
    a_std: float = 1.0


def _is_linear(node) -> bool:
    return isinstance(node, dict) and "w" in node and hasattr(node["w"], "ndim")


def attach_lora(key, params: dict, spec: LoRASpec) -> dict:
    """Return a copy of ``params`` with GeoLoRA (+GeoDoRA) side-cars attached
    to every linear whose name is in ``spec.targets``. Works on stacked
    (scan-over-layers) leaves: side-cars get the same leading layer dims."""
    counter = [0]

    def walk(node, name):
        if _is_linear(node):
            if name in spec.targets and node["w"].ndim >= 2:
                counter[0] += 1
                sub = jax.random.fold_in(key, counter[0])
                new = add_lora(sub, node, spec.rank, node["w"].dtype,
                               a_std=spec.a_std)
                if spec.dora:
                    new = add_dora(new)
                return new
            return node
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name) for v in node)
        return node

    return walk(params, "")


# ----------------------------------------------------------------------
# trainable/frozen partition
def trainable_mask(params, extra_subtrees: Tuple[str, ...] = ()) -> dict:
    """Bool pytree: True where the leaf is node-trainable under the paper's
    protocol (lora_B, dora_m, adapters, small shared heads)."""
    marked = LOCAL_SUBTREES + SHARED_SUBTREES + tuple(extra_subtrees)

    def walk(node, name, inside):
        inside = inside or name in marked
        if isinstance(node, dict):
            return {k: walk(v, k, inside) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name, inside) for v in node)
        return bool(inside or name in TRAINABLE_LEAVES)

    return walk(params, "", False)


def shipped_mask(trainable) -> dict:
    """Bool pytree over a trainable tree: True for side-cars shipped to the
    server each round (lora_B / dora_m / shared heads), False for node-local
    params (the W_mk adapters, paper: 'never leave the node')."""
    def walk(node, name, local):
        local = local or name in LOCAL_SUBTREES
        if isinstance(node, dict):
            return {k: walk(v, k, local) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name, local) for v in node)
        if node is None:
            return None
        return not local
    return walk(trainable, "", False)


def partition(params, mask):
    """Split params into (trainable, frozen) trees with None placeholders."""
    train = jax.tree.map(lambda p, m: p if m else None, params, mask,
                         is_leaf=lambda x: x is None)
    frozen = jax.tree.map(lambda p, m: None if m else p, params, mask,
                          is_leaf=lambda x: x is None)
    return train, frozen


def combine(train, frozen):
    return jax.tree.map(lambda t, f: t if f is None else f, train, frozen,
                        is_leaf=lambda x: x is None)


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree) if hasattr(x, "size"))


def param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


# ----------------------------------------------------------------------
def merge_lora(params: dict, scale: float = 1.0) -> dict:
    """Fold Delta-W = scale * A@B (and the DoRA normalisation) into ``w`` and
    drop the side-cars (deployment export)."""
    from repro.models.common import dora_column_norm

    def walk(node, name):
        if _is_linear(node) and "lora_A" in node:
            w = node["w"].astype(jnp.float32)
            a = node["lora_A"].astype(jnp.float32)
            b = node["lora_B"].astype(jnp.float32)
            new_w = w + scale * (a @ b)
            if "dora_m" in node:
                norm = dora_column_norm(node["w"], node["lora_A"],
                                        scale * node["lora_B"])
                new_w = new_w * (node["dora_m"].astype(jnp.float32)
                                 / norm)[..., None, :]
            return {"w": new_w.astype(node["w"].dtype)}
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, name) for v in node)
        return node

    return walk(params, "")
