"""Gram matrices + centered kernel alignment (paper Eqs. 1-2).

The paper's alignment signal: each node pools its anchor-set activations,
forms the B x B cosine-similarity Gram matrix G^(k) (Eq. 1), and minimises
1 - CKA(G^(k), G_bar) against the server's consensus Gram (Eq. 2).  Only the
Gram matrix crosses the wire — never activations — which is the privacy
argument (Table 2: "Gram m. (private)").

The paper writes CKA(X, Y) = tr(X Y^T) / (||X||_F ||Y||_F) on the Gram
matrices directly (uncentered).  Kornblith et al.'s CKA double-centers the
Grams first; we default to the paper's formula and expose ``center=True``
for the Kornblith variant (both are tested for the invariances that make
the alignment meaningful).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def cosine_gram(z: Array, eps: float = 1e-8) -> Array:
    """Eq. 1: pairwise cosine-similarity kernel of pooled embeddings.
    z: (B, D) -> (B, B).  Mirrored by the Pallas kernel in
    ``repro.kernels.gram``; this is the reference implementation."""
    z32 = z.astype(jnp.float32)
    norms = jnp.sqrt(jnp.maximum((z32 * z32).sum(-1, keepdims=True), eps))
    zn = z32 / norms
    return zn @ zn.T


def _center(g: Array) -> Array:
    n = g.shape[0]
    h = jnp.eye(n, dtype=g.dtype) - 1.0 / n
    return h @ g @ h


def cka(gx: Array, gy: Array, *, center: bool = False,
        eps: float = 1e-12) -> Array:
    """Eq. 2: CKA(X, Y) = tr(X Y^T) / (||X||_F ||Y||_F)."""
    gx = gx.astype(jnp.float32)
    gy = gy.astype(jnp.float32)
    if center:
        gx, gy = _center(gx), _center(gy)
    num = (gx * gy).sum()
    den = jnp.sqrt(jnp.maximum((gx * gx).sum(), eps)) * \
        jnp.sqrt(jnp.maximum((gy * gy).sum(), eps))
    return num / jnp.maximum(den, eps)


def geo_alignment_loss(pooled_anchors: Array, consensus_gram: Array, *,
                       center: bool = False) -> Array:
    """Paper Eq. 3 regulariser term: 1 - CKA(G_adapted^(k), G_bar).
    ``pooled_anchors``: (B_anchor, d_model) pooled activations of the public
    anchor set through the node's full pipeline (adapter + adapted model)."""
    g_local = cosine_gram(pooled_anchors)
    return 1.0 - cka(g_local, jax.lax.stop_gradient(consensus_gram),
                     center=center)


def consensus_gram(node_grams: Array, mask: Array = None,
                   fallback: Array = None) -> Array:
    """Server side: G_bar = mean_k G^(k). node_grams: (K, B, B) (the server
    may only ever see these Gram matrices, not activations).  With a
    participation ``mask`` (K,) the mean runs over REPORTING nodes only —
    Eq. 2 averaged over whichever nodes upload this round.  ``fallback``
    (B, B) is returned when the mask selects NO reporters (an async round
    with no fresh-enough deliveries keeps the previous consensus instead
    of collapsing to the zero Gram)."""
    if mask is None:
        return node_grams.mean(axis=0)
    m = mask.astype(jnp.float32)
    num = (m[:, None, None] * node_grams.astype(jnp.float32)).sum(axis=0)
    mean = num / jnp.maximum(m.sum(), 1.0)
    if fallback is None:
        return mean
    return jnp.where(m.sum() > 0.0, mean,
                     fallback.astype(jnp.float32))


def pairwise_cka(grams: Array, *, center: bool = False) -> Array:
    """(K, B, B) -> (K, K) matrix of CKA values between node geometries —
    the paper's measure of cross-modality representational convergence."""
    k = grams.shape[0]
    fn = jax.vmap(jax.vmap(lambda a, b: cka(a, b, center=center),
                           (None, 0)), (0, None))
    return fn(grams, grams)


def mean_offdiag_cka(grams: Array, *, center: bool = False,
                     mask: Array = None) -> Array:
    """Mean off-diagonal pairwise CKA over K node Grams — the per-round
    cross-modality alignment metric reported by the federation drivers.
    With a participation ``mask`` (K,), only pairs of REPORTING nodes
    count (0.0 when fewer than two report)."""
    k = grams.shape[0]
    pair = pairwise_cka(grams, center=center)
    if mask is None:
        return (pair.sum() - jnp.trace(pair)) / max(k * (k - 1), 1)
    m = mask.astype(jnp.float32)
    w = m[:, None] * m[None, :] * (1.0 - jnp.eye(k, dtype=jnp.float32))
    return (pair * w).sum() / jnp.maximum(w.sum(), 1.0)
