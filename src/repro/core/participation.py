"""Participation layer: sampled cohorts, straggler masks, sampler state.

The paper's server protocol (Eqs. 2 & 6) averages side-cars and consensus
Grams over *whichever nodes report* — nothing in the math requires full
synchronous participation.  Real cross-silo deployments sample a cohort per
round and tolerate dropouts/stragglers, so the engine threads a
``ParticipationPlan`` through every level of the stack:

  - **full** — every node, every round (the legacy path; callers that pass
    this plan are routed onto the exact pre-participation compiled round);
  - **uniform** — C of K nodes per round, sampled without replacement,
    BUCKET-STRATIFIED: cohort slots are allocated to the engine's width
    buckets by largest-remainder proportional allocation with at least
    one slot per bucket (static per-bucket cohort sizes — what lets the
    compiled round GATHER the cohort rows into compact ``(c_b, ...)``
    states and pay compute proportional to C, not K), then sampled
    uniformly within each bucket.  Inclusion probability is c_b / k_b per
    bucket (proportional up to the +-1 slot granularity), not exactly
    uniform over all C-subsets of K — see ``allocate_cohort``;
  - **precision** — like ``uniform`` but within-bucket sampling is
    proportional to each node's LAST reported LAP precision (Gumbel-top-k
    over ``log p_k``), so unreliable nodes are polled less often; the
    carried precision estimates ride the sampler state;
  - **dropout** — a deterministic straggler simulator: every node
    independently fails to report with probability ``dropout_rate`` (drawn
    from the carried RNG, so runs are reproducible).  The cohort size
    varies per round, so execution falls back to the masked path (all K
    compute, non-reporters' state carried through untouched);
  - **nodes** — a fixed explicit cohort (deterministic stragglers /
    partial-deployment configs; also the oracle-equivalence test hook);
  - **async** — the FedBuff-style asynchronous regime: every node runs a
    deterministic on-device *lag-and-failure simulator* from the carried
    RNG.  An idle node starts a round of local work (unless it crashed,
    ``crash_rate``/``rejoin_rate`` Markov chain, or transiently fails to
    report, ``transient_rate``); its finished report — shipped side-car
    values, anchor Gram panel, LAP precision — lands in a carried REPORT
    BUFFER with a lag drawn from ``lag_dist`` (fixed ``lag`` rounds, or
    geometric with parameter ``lag_p``, capped at ``max_lag``).  The
    server each round applies a **staleness-weighted precision average**
    over exactly the reports whose lag expires that round (weight
    ``p_k * f(lag_k)`` with ``staleness='poly'``
    ``(1+lag)^-staleness_alpha`` or a ``'cutoff'`` bounded-staleness
    schedule; ``max_staleness`` additionally hard-gates either), and the
    Gram/CKA consensus averages only fresh-enough reports.  A node whose
    report is in flight is busy (it does not start new work until the
    report lands); a crash loses the in-flight report.  A **quarantine
    guard** checks every report ON DEVICE before it enters the buffer:
    non-finite values or a shipped-side-car norm above
    ``quarantine_norm`` zero the report's contribution and bump a
    per-node quarantine counter instead of poisoning the global model.
    ``poison_nodes`` is the fault injector: those nodes' uplinks are
    corrupted to NaN every round (the guard must catch all of them).

Sampling runs ON DEVICE from the carried sampler state (an RNG key, plus
precision estimates for ``precision``), so it composes with the fused
``lax.scan`` round blocks: the state is part of the donated block carry and
a checkpoint of the carry resumes the sampling stream bit-identically.  All
sampling functions are pure jax and run eagerly too — the sequential
reference federation calls the SAME functions on host to produce the oracle
cohort sequence for the engine-equivalence tests.

Semantics of non-participation: a node that is not sampled (or drops out)
does NOTHING that round — its trainables, optimizer moments and RNG key
carry through untouched and it contributes nothing to the consensus Gram,
the LAP precision pool, or the side-car average.  It still RECEIVES the
server broadcast (downlink at next round start), matching cross-device
FedAvg semantics and keeping the engine's replicated-shipped-row invariant.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

STRATEGIES = ("full", "uniform", "precision", "dropout", "nodes", "async")

LAG_DISTS = ("fixed", "geometric")
STALENESS_SCHEDULES = ("poly", "cutoff")


@dataclass(frozen=True)
class ParticipationPlan:
    """Static participation config (hashable: keys the engine's compiled
    round/block caches).  ``seed`` feeds the carried sampler RNG;
    ``compact`` opts the static-cohort strategies out of gather-compact
    execution (masked fallback — the two paths are equivalence-tested)."""
    strategy: str = "full"
    cohort_size: Optional[int] = None          # uniform | precision
    dropout_rate: float = 0.25                 # dropout
    nodes: Tuple[int, ...] = ()                # nodes (fixed cohort)
    seed: int = 0
    compact: bool = True
    # --- async strategy: lag distribution + failure simulator ----------
    lag_dist: str = "fixed"                    # "fixed" | "geometric"
    lag: int = 1                               # fixed lag, rounds
    lag_p: float = 0.5                         # geometric success prob
    max_lag: int = 4                           # cap on any drawn lag
    transient_rate: float = 0.0                # per-round non-report prob
    crash_rate: float = 0.0                    # online -> offline prob
    rejoin_rate: float = 0.5                   # offline -> online prob
    # --- async server step: staleness weighting + quarantine -----------
    staleness: str = "poly"                    # "poly" | "cutoff"
    staleness_alpha: float = 1.0               # poly exponent
    max_staleness: Optional[int] = None        # hard gate on lag, rounds
    quarantine_norm: float = 1e6               # report-norm guard
    poison_nodes: Tuple[int, ...] = ()         # fault injection (NaN uplink)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown participation strategy "
                             f"{self.strategy!r}; expected one of "
                             f"{STRATEGIES}")
        if self.strategy in ("uniform", "precision") \
                and not self.cohort_size:
            raise ValueError(f"strategy {self.strategy!r} needs a "
                             f"cohort_size")
        if self.strategy == "nodes" and not self.nodes:
            raise ValueError("strategy 'nodes' needs a non-empty node set")
        if self.strategy == "dropout" \
                and not 0.0 <= self.dropout_rate < 1.0:
            raise ValueError(f"dropout_rate {self.dropout_rate} outside "
                             f"[0, 1)")
        if self.strategy == "async":
            if self.lag_dist not in LAG_DISTS:
                raise ValueError(f"unknown lag_dist {self.lag_dist!r}; "
                                 f"expected one of {LAG_DISTS}")
            if self.staleness not in STALENESS_SCHEDULES:
                raise ValueError(
                    f"unknown staleness schedule {self.staleness!r}; "
                    f"expected one of {STALENESS_SCHEDULES}")
            if self.lag < 0 or self.max_lag < 0:
                raise ValueError(f"lag {self.lag} / max_lag "
                                 f"{self.max_lag} must be >= 0")
            if self.lag_dist == "fixed" and self.lag > self.max_lag:
                raise ValueError(f"fixed lag {self.lag} exceeds max_lag "
                                 f"{self.max_lag}")
            if not 0.0 < self.lag_p <= 1.0:
                raise ValueError(f"lag_p {self.lag_p} outside (0, 1]")
            for name in ("transient_rate", "crash_rate", "rejoin_rate"):
                v = getattr(self, name)
                if not 0.0 <= v <= 1.0:
                    raise ValueError(f"{name} {v} outside [0, 1]")
            if self.crash_rate >= 1.0:
                raise ValueError("crash_rate 1.0 permanently kills every "
                                 "node; use < 1.0")
            if self.max_staleness is not None and self.max_staleness < 0:
                raise ValueError(f"max_staleness {self.max_staleness} "
                                 f"must be >= 0")
            if self.quarantine_norm <= 0.0:
                raise ValueError(f"quarantine_norm {self.quarantine_norm} "
                                 f"must be > 0")


def normalize(plan) -> Optional[ParticipationPlan]:
    """None / "full" / full-plan -> None (the legacy engine path, which is
    bit-identical to the pre-participation engine); strings become plans."""
    if plan is None:
        return None
    if isinstance(plan, str):
        plan = ParticipationPlan(strategy=plan)
    if plan.strategy == "full":
        return None
    return plan


def static_cohort(plan: ParticipationPlan) -> bool:
    """True when the per-round cohort size is a compile-time constant —
    the strategies the engine can execute gather-compact."""
    return plan.strategy in ("uniform", "precision", "nodes")


def init_state(plan: Optional[ParticipationPlan], n_nodes: int):
    """Carried sampler state (rides the fused-block carry and the
    checkpoint): an RNG key for the stochastic strategies, plus the
    running per-node precision estimates (ENGINE ROW order) for
    ``precision``.  ``None`` for stateless strategies."""
    plan = normalize(plan)
    if plan is None or plan.strategy == "nodes":
        return None
    state = {"key": jax.random.PRNGKey(plan.seed)}
    if plan.strategy == "precision":
        state["prev_p"] = jnp.ones((n_nodes,), jnp.float32)
    if plan.strategy == "async":
        k = n_nodes
        # countdown: rounds until the in-flight report lands; -1 == idle
        # (no report in flight).  lag: the drawn lag of the in-flight
        # report (frozen at ship time, so the server can weight by it at
        # delivery).  offline: the crash Markov-chain state.  quarantined:
        # cumulative per-node count of reports the guard rejected.
        state["offline"] = jnp.zeros((k,), jnp.float32)
        state["countdown"] = jnp.full((k,), -1, jnp.int32)
        state["lag"] = jnp.zeros((k,), jnp.int32)
        state["quarantined"] = jnp.zeros((k,), jnp.int32)
    return state


def allocate_cohort(c: int, group_sizes) -> Tuple[int, ...]:
    """Largest-remainder proportional allocation of C cohort slots over the
    width buckets: static per-bucket cohort sizes (sum == C, each <= the
    bucket size) so the compiled round can gather fixed-shape cohort
    states.  Deterministic: ties broken by bucket index.

    Every non-empty bucket is guaranteed at least one slot (requires
    C >= number of non-empty buckets), so no node is permanently starved
    by a zero-quota bucket — the allocation is static across rounds, which
    is what makes the compacted shapes compile-time constants.  Empty
    buckets (a degenerate layout some callers produce for modality sets
    with no nodes) get zero slots rather than tripping the invariant.
    Within a bucket, sampling is uniform; ACROSS buckets inclusion
    probability is c_b / k_b (proportional up to the +-1 slot
    granularity), i.e. the strategies are bucket-STRATIFIED rather than
    exactly uniform over all C-subsets of K — the price of cohort-shaped
    compute.  Use ``dropout`` or an explicit ``nodes`` plan when exact
    global semantics matter."""
    k = sum(group_sizes)
    live = [b for b, s in enumerate(group_sizes) if s > 0]
    n_groups = len(live)
    if not 1 <= c <= k:
        raise ValueError(f"cohort_size {c} outside [1, {k}]")
    if c < n_groups:
        raise ValueError(
            f"cohort_size {c} < {n_groups} non-empty width buckets: the "
            f"static per-bucket allocation would permanently starve a "
            f"bucket; use cohort_size >= {n_groups}, an explicit nodes= "
            f"plan, or the dropout strategy")
    sizes = [group_sizes[b] for b in live]
    # one guaranteed slot per non-empty bucket, remainder by
    # largest-remainder on the proportional quotas of the leftover slots
    base = [1] * n_groups
    rest = c - n_groups
    quotas = [rest * (s - 1) / max(k - n_groups, 1) for s in sizes]
    add = [min(int(q), s - 1) for q, s in zip(quotas, sizes)]
    rem = rest - sum(add)
    order = sorted(range(n_groups),
                   key=lambda b: (add[b] - quotas[b], b))
    for b in order:
        if rem == 0:
            break
        room = sizes[b] - 1 - add[b]
        take = min(room, 1)
        add[b] += take
        rem -= take
    # any residue (buckets at capacity) goes wherever room remains
    for b in range(n_groups):
        while rem > 0 and base[b] + add[b] < sizes[b]:
            add[b] += 1
            rem -= 1
    base = [b_ + a for b_, a in zip(base, add)]
    assert sum(base) == c and all(1 <= cb <= s for cb, s
                                  in zip(base, sizes))
    out = [0] * len(group_sizes)
    for b, cb in zip(live, base):
        out[b] = cb
    return tuple(out)


def _guarded(keep: Array) -> Array:
    """Never let every node drop out (an empty round divides by zero and
    stalls the protocol): an all-dropped draw degrades to full
    participation, which is what a production server waiting on a quorum
    would effectively do."""
    return jnp.where(keep.any(), keep,
                     jnp.ones_like(keep)).astype(jnp.float32)


def sample_rows(plan: ParticipationPlan, state, groups):
    """One round of cohort sampling.  ``groups`` is the engine's bucket
    layout as a tuple of tuples of CANONICAL node ids (row order within
    each bucket).  Pure jax — traceable inside the compiled round/block
    AND runnable eagerly by the sequential oracle.

    Returns ``(row_masks, cohort_rows, new_state)``:
      - ``row_masks[b]``: (k_b,) float32 0/1 participation per bucket row;
      - ``cohort_rows[b]``: (c_b,) int32 participating rows (sorted), or
        ``None`` for strategies without a static cohort (dropout);
      - ``new_state``: advanced sampler state (same structure as input).
    """
    sizes = tuple(len(g) for g in groups)

    if plan.strategy == "nodes":
        chosen = set(plan.nodes)
        rows = tuple(
            jnp.asarray([r for r, i in enumerate(g) if i in chosen],
                        jnp.int32) for g in groups)
        if sum(int(r.shape[0]) for r in rows) != len(chosen):
            raise ValueError(f"plan nodes {plan.nodes} are not all present "
                             f"in the federation's {sum(sizes)} nodes")
        masks = tuple(jnp.zeros((s,), jnp.float32).at[r].set(1.0)
                      for s, r in zip(sizes, rows))
        return masks, rows, state

    key, sub = jax.random.split(state["key"])
    new_state = dict(state, key=key)

    if plan.strategy == "dropout":
        keep = jax.random.bernoulli(
            sub, 1.0 - plan.dropout_rate, (sum(sizes),))
        mask = _guarded(keep)
        off, masks = 0, []
        for s in sizes:
            masks.append(mask[off:off + s])
            off += s
        return tuple(masks), None, new_state

    # uniform / precision: static per-bucket cohort sizes
    c_bs = allocate_cohort(plan.cohort_size, sizes)
    gkeys = jax.random.split(sub, len(sizes))
    rows, masks, off = [], [], 0
    for b, (s, cb) in enumerate(zip(sizes, c_bs)):
        if plan.strategy == "precision":
            # Gumbel-top-k over log p: draws c_b rows WITHOUT replacement
            # with inclusion proportional-ish to the carried precision
            # estimates, so low-precision (unreliable) nodes are polled
            # less often but never starved outright
            p = jnp.maximum(new_state["prev_p"][off:off + s], 1e-12)
            g = -jnp.log(-jnp.log(jnp.maximum(
                jax.random.uniform(gkeys[b], (s,)), 1e-12)))
            scores = jnp.log(p) + g
        else:
            scores = jax.random.uniform(gkeys[b], (s,))
        # top-c_b rows, then sorted so gather order is row order
        idx = jnp.sort(jax.lax.top_k(scores, cb)[1].astype(jnp.int32)) \
            if cb else jnp.zeros((0,), jnp.int32)
        rows.append(idx)
        masks.append(jnp.zeros((s,), jnp.float32).at[idx].set(1.0)
                     if cb else jnp.zeros((s,), jnp.float32))
        off += s
    return tuple(masks), tuple(rows), new_state


def async_events(plan: ParticipationPlan, state):
    """One round of the async lag-and-failure simulator.  Pure jax —
    traceable inside the compiled round/block AND runnable eagerly by
    the sequential oracle (identical event streams is the equivalence
    contract).

    All control arrays are (K,) in ENGINE ROW order and ride the carried
    sampler state.  Per round, in order:

      1. crash / rejoin: each online node goes offline with
         ``crash_rate``; each offline node comes back with
         ``rejoin_rate``.  A crash LOSES the in-flight report (its
         countdown resets to idle).
      2. transient non-report: an idle online node skips this round with
         ``transient_rate``.
      3. start: every idle, online, non-transient node begins a round of
         local work and SHIPS its report with a freshly drawn lag
         (``lag_dist``: fixed ``lag``, or geometric with success prob
         ``lag_p``; either clipped to ``max_lag``).  Lag 0 delivers this
         same round; lag L delivers L rounds later.  The node is busy
         (does not start again) until its report lands.

    Returns ``(start, lag_draw, new_state)`` where ``start`` is the (K,)
    float32 0/1 mask of nodes doing local work this round, ``lag_draw``
    is the (K,) int32 lag each starter shipped with (0 elsewhere), and
    ``new_state`` has advanced key/offline — the caller (the engine's
    async round body or the eager oracle) writes countdown/lag at the
    rows that pass its quarantine guard."""
    key, k_crash, k_rejoin, k_trans, k_lag = \
        jax.random.split(state["key"], 5)
    offline = state["offline"]
    countdown = state["countdown"]

    crash = jax.random.bernoulli(
        k_crash, plan.crash_rate, offline.shape).astype(jnp.float32)
    rejoin = jax.random.bernoulli(
        k_rejoin, plan.rejoin_rate, offline.shape).astype(jnp.float32)
    new_offline = jnp.where(offline > 0, 1.0 - rejoin, crash)
    # a crash kills the in-flight report
    countdown = jnp.where(new_offline > 0,
                          jnp.int32(-1), countdown)

    transient = jax.random.bernoulli(
        k_trans, plan.transient_rate, offline.shape).astype(jnp.float32)
    idle = (countdown < 0).astype(jnp.float32)
    start = idle * (1.0 - new_offline) * (1.0 - transient)

    if plan.lag_dist == "fixed":
        lag_draw = jnp.full(offline.shape, plan.lag, jnp.int32)
    else:
        u = jnp.maximum(jax.random.uniform(k_lag, offline.shape), 1e-12)
        # number of failures before first success, p = lag_p
        lag_draw = jnp.floor(
            jnp.log1p(-u * (1.0 - 1e-12)) /
            jnp.log1p(-jnp.float32(min(plan.lag_p, 1.0 - 1e-7)))
        ).astype(jnp.int32)
    lag_draw = (jnp.clip(lag_draw, 0, plan.max_lag)
                * start.astype(jnp.int32))

    new_state = dict(state, key=key, offline=new_offline,
                     countdown=countdown)
    return start, lag_draw, new_state


def poison_mask(plan: ParticipationPlan, n_nodes: int,
                row_of_node=None) -> Array:
    """(K,) float32 0/1 mask of fault-injected rows.  ``plan.poison_nodes``
    names CANONICAL node ids; ``row_of_node`` maps canonical id -> engine
    row (identity when omitted, e.g. in the sequential oracle)."""
    m = [0.0] * n_nodes
    for i in plan.poison_nodes:
        r = row_of_node[i] if row_of_node is not None else i
        m[r] = 1.0
    return jnp.asarray(m, jnp.float32)


def update_state(plan: ParticipationPlan, state, mask_rows: Array,
                 precisions_rows: Array):
    """Post-round sampler-state update: the ``precision`` strategy folds
    this round's reported LAP precisions into its carried estimates at the
    reporting rows (non-reporters keep their previous estimate).  Both
    arrays are (K,) in ENGINE ROW order."""
    if plan.strategy != "precision" or state is None:
        return state
    prev = state["prev_p"]
    new_p = jnp.where(mask_rows > 0,
                      precisions_rows.astype(jnp.float32), prev)
    return dict(state, prev_p=new_p)


def plan_meta(plan: Optional[ParticipationPlan]):
    """JSON-serialisable plan description for checkpoint metadata."""
    if plan is None:
        return None
    return {"strategy": plan.strategy, "cohort_size": plan.cohort_size,
            "dropout_rate": plan.dropout_rate, "nodes": list(plan.nodes),
            "seed": plan.seed, "compact": plan.compact,
            "lag_dist": plan.lag_dist, "lag": plan.lag,
            "lag_p": plan.lag_p, "max_lag": plan.max_lag,
            "transient_rate": plan.transient_rate,
            "crash_rate": plan.crash_rate,
            "rejoin_rate": plan.rejoin_rate,
            "staleness": plan.staleness,
            "staleness_alpha": plan.staleness_alpha,
            "max_staleness": plan.max_staleness,
            "quarantine_norm": plan.quarantine_norm,
            "poison_nodes": list(plan.poison_nodes)}


def plan_from_meta(meta) -> Optional[ParticipationPlan]:
    if not meta:
        return None
    return ParticipationPlan(
        strategy=meta["strategy"], cohort_size=meta["cohort_size"],
        dropout_rate=meta["dropout_rate"], nodes=tuple(meta["nodes"]),
        seed=meta["seed"], compact=meta.get("compact", True),
        lag_dist=meta.get("lag_dist", "fixed"), lag=meta.get("lag", 1),
        lag_p=meta.get("lag_p", 0.5), max_lag=meta.get("max_lag", 4),
        transient_rate=meta.get("transient_rate", 0.0),
        crash_rate=meta.get("crash_rate", 0.0),
        rejoin_rate=meta.get("rejoin_rate", 0.5),
        staleness=meta.get("staleness", "poly"),
        staleness_alpha=meta.get("staleness_alpha", 1.0),
        max_staleness=meta.get("max_staleness"),
        quarantine_norm=meta.get("quarantine_norm", 1e6),
        poison_nodes=tuple(meta.get("poison_nodes", ())))
