"""Server-side aggregation: FedAvg, GeoLoRA B-averaging (Eq. 4) and GeoDoRA
magnitude/direction averaging (Eq. 5), with optional precision weights.

Because ``lora_A`` is frozen and identical across nodes, averaging the
``lora_B`` factors is *exactly* equivalent to averaging the full low-rank
updates:  mean_k(B_k) @ A == mean_k(B_k @ A)  — the property that makes
Eq. 4 sound (and that heterogeneous-A schemes like FedIT get wrong, see
paper Table 2).  Property-tested in tests/test_properties.py.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def weighted_mean_trees(trees: Sequence, weights: Optional[Array] = None):
    """Weighted average of pytrees (FedAvg core). ``weights`` sums to 1."""
    k = len(trees)
    if weights is None:
        weights = jnp.full((k,), 1.0 / k, jnp.float32)

    def avg(*leaves):
        acc = jnp.zeros_like(leaves[0], dtype=jnp.float32)
        for w, leaf in zip(weights, leaves):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *trees)


def fedavg(node_updates: Sequence, weights: Optional[Array] = None):
    """Plain FedAvg [McMahan 2017] — the paper's baseline aggregator."""
    return weighted_mean_trees(node_updates, weights)


def aggregate_geolora(node_trainables: Sequence,
                      weights: Optional[Array] = None):
    """Eq. 4 (+5): average the node-trainable side-car trees (lora_B,
    dora_m, shared heads).  With DoRA side-cars present this realises Eq. 5:
    the averaged magnitude multiplies the direction
    (theta_fixed + mean(B) A) / ||...||_c at apply time (see
    ``repro.models.common.linear``), so averaging (B_k, m_k) is the whole
    server step."""
    return weighted_mean_trees(node_trainables, weights)


def weighted_average_stacked(stacked, weights: Array, shipped_mask):
    """Server step on node-STACKED trees (Eqs. 4-6 in one pass): leaves
    marked shipped are precision-weight-averaged along the leading node axis
    and broadcast back to every node; node-local leaves (adapters W_mk) pass
    through untouched.  ``shipped_mask`` is a static bool pytree matching
    ``stacked`` (``None`` placeholders align).  The single-bucket case of
    ``weighted_average_bucketed``, kept as the simple-layout entry point."""
    return weighted_average_bucketed(
        (stacked,), weights, (shipped_mask,), (int(weights.shape[0]),))[0]


def bucketed_partial_sums(bucket_trees, weights: Array, shipped_masks,
                          bucket_sizes):
    """Per-bucket weighted partial sums of the SHIPPED leaves, reduced
    across buckets into one tree (float32; ``None`` at non-shipped leaves).
    ``weights`` is (K,) in bucket-concatenated row order.  Shipped leaves
    must have identical shapes in every bucket."""
    is_none = lambda x: x is None
    partials, off = [], 0
    for tree, mask, kb in zip(bucket_trees, shipped_masks, bucket_sizes):
        w = weights[off:off + kb].astype(jnp.float32)
        off += kb

        def part(leaf, m, w=w):
            if leaf is None or not m:
                return None
            return jnp.tensordot(w, leaf.astype(jnp.float32), axes=1)

        partials.append(jax.tree.map(part, tree, mask, is_leaf=is_none))
    total = partials[0]
    for p in partials[1:]:
        total = jax.tree.map(
            lambda a, b: None if a is None else a + b, total, p,
            is_leaf=is_none)
    return total


def broadcast_into_buckets(bucket_trees, shipped_masks, total):
    """Broadcast the reduced shipped average back onto every node row of
    every bucket; non-shipped leaves pass through untouched."""
    is_none = lambda x: x is None

    def bcast(leaf, m, a):
        if leaf is None or not m:
            return leaf
        return jnp.broadcast_to(a.astype(leaf.dtype)[None], leaf.shape)

    return tuple(
        jax.tree.map(bcast, tree, mask, total, is_leaf=is_none)
        for tree, mask in zip(bucket_trees, shipped_masks))


def weighted_average_bucketed(bucket_trees, weights: Array, shipped_masks,
                              bucket_sizes, part_mask: Array = None):
    """Server step across width BUCKETS: ``bucket_trees[b]`` stacks the
    bucket's nodes along a leading axis; ``weights`` is (K,) in
    bucket-concatenated row order.  Shipped leaves (identical shapes in
    every bucket) are precision-weight-averaged across ALL buckets via
    per-bucket partial sums, then broadcast back into each bucket;
    node-local leaves (the W_mk adapters, whose widths differ per bucket)
    pass through untouched.  The sharded engine path reuses the two halves
    (``bucketed_partial_sums`` / ``broadcast_into_buckets``) with a psum
    between them.

    ``part_mask`` (K,) 0/1 enables mask-aware normalisation for partial
    participation: non-reporting rows are zeroed out of the average and
    the weights are renormalised over the reporting cohort, so the
    broadcast value is the average of exactly the nodes that reported
    (Eq. 4/5 over the cohort).  ``None`` keeps the legacy behaviour
    bit-identically (weights used as given, assumed normalised)."""
    if part_mask is not None:
        w = weights.astype(jnp.float32) * part_mask.astype(jnp.float32)
        weights = w / jnp.maximum(w.sum(), 1e-12)
    return broadcast_into_buckets(
        bucket_trees, shipped_masks,
        bucketed_partial_sums(bucket_trees, weights, shipped_masks,
                              bucket_sizes))


def weighted_average_reports(report_tree, weights: Array):
    """Weighted average over the async REPORT BUFFER: every leaf of
    ``report_tree`` stacks the K nodes' buffered shipped side-cars along a
    leading axis (identical shapes across nodes — only shipped leaves are
    buffered), ``weights`` is (K,) and already staleness-normalised (it
    may be all-zero on a no-delivery round, in which case the result is
    the zero tree and the caller keeps the previous global value).
    Returns the reduced float32 tree."""
    w = weights.astype(jnp.float32)
    return jax.tree.map(
        lambda leaf: jnp.tensordot(w, leaf.astype(jnp.float32), axes=1),
        report_tree)


def comm_bytes_per_round(trainable_tree, gram_side: int = 0) -> int:
    """Uplink bytes a node ships per round under the paper's protocol:
    the trainable side-cars + the B x B Gram matrix (f32)."""
    from repro.core.lora import param_bytes
    return param_bytes(trainable_tree) + gram_side * gram_side * 4
