"""Server-side aggregation: FedAvg, GeoLoRA B-averaging (Eq. 4) and GeoDoRA
magnitude/direction averaging (Eq. 5), with optional precision weights.

Because ``lora_A`` is frozen and identical across nodes, averaging the
``lora_B`` factors is *exactly* equivalent to averaging the full low-rank
updates:  mean_k(B_k) @ A == mean_k(B_k @ A)  — the property that makes
Eq. 4 sound (and that heterogeneous-A schemes like FedIT get wrong, see
paper Table 2).  Property-tested in tests/test_properties.py.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def weighted_mean_trees(trees: Sequence, weights: Optional[Array] = None):
    """Weighted average of pytrees (FedAvg core). ``weights`` sums to 1."""
    k = len(trees)
    if weights is None:
        weights = jnp.full((k,), 1.0 / k, jnp.float32)

    def avg(*leaves):
        acc = jnp.zeros_like(leaves[0], dtype=jnp.float32)
        for w, leaf in zip(weights, leaves):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *trees)


def fedavg(node_updates: Sequence, weights: Optional[Array] = None):
    """Plain FedAvg [McMahan 2017] — the paper's baseline aggregator."""
    return weighted_mean_trees(node_updates, weights)


def aggregate_geolora(node_trainables: Sequence,
                      weights: Optional[Array] = None):
    """Eq. 4 (+5): average the node-trainable side-car trees (lora_B,
    dora_m, shared heads).  With DoRA side-cars present this realises Eq. 5:
    the averaged magnitude multiplies the direction
    (theta_fixed + mean(B) A) / ||...||_c at apply time (see
    ``repro.models.common.linear``), so averaging (B_k, m_k) is the whole
    server step."""
    return weighted_mean_trees(node_trainables, weights)


def weighted_average_stacked(stacked, weights: Array, shipped_mask):
    """Server step on node-STACKED trees (Eqs. 4-6 in one pass): leaves
    marked shipped are precision-weight-averaged along the leading node axis
    and broadcast back to every node; node-local leaves (adapters W_mk) pass
    through untouched.  ``shipped_mask`` is a static bool pytree matching
    ``stacked`` (``None`` placeholders align)."""
    w = weights.astype(jnp.float32)

    def avg(leaf, shipped):
        if leaf is None or not shipped:
            return leaf
        a = jnp.tensordot(w, leaf.astype(jnp.float32),
                          axes=1).astype(leaf.dtype)
        return jnp.broadcast_to(a[None], leaf.shape)

    return jax.tree.map(avg, stacked, shipped_mask,
                        is_leaf=lambda x: x is None)


def comm_bytes_per_round(trainable_tree, gram_side: int = 0) -> int:
    """Uplink bytes a node ships per round under the paper's protocol:
    the trainable side-cars + the B x B Gram matrix (f32)."""
    from repro.core.lora import param_bytes
    return param_bytes(trainable_tree) + gram_side * gram_side * 4
