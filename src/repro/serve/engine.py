"""Continuous-batching decode engine: fused decode blocks over a donated
slot-stacked cache pool, with a resilience layer.

The legacy loop (``examples/serve_decode.py``) pays one jit dispatch plus
a blocking host readback per decoded token and head-of-line blocks the
whole batch on its slowest sequence.  This engine applies the round
engine's idioms to serving:

  - the S request slots live in ONE slot-stacked cache pool
    (``serve.pool``) with per-slot positions, ``active`` / ``stopped``
    masks, a per-slot token budget, and the last sampled token — all
    device-resident and DONATED to the compiled step, so pool buffers
    alias across blocks like round state aliases across rounds;
  - ``M = block_steps`` decode steps are fused into one jitted
    ``lax.scan`` (``_block_impl``): greedy/temperature sampling and
    stop-token accounting run ON DEVICE in the carry, tokens accumulate
    into an (M, S) device buffer, and the host pays exactly one dispatch
    and one readback per M tokens-per-slot — the serving analogue of
    ``RoundEngine.run_block``;
  - new requests are admitted MID-DECODE: prefill runs as its own
    compiled call (per prompt length), and the resulting single-request
    cache is scattered into a free slot (``scatter_slot``) without
    touching in-flight slots or recompiling anything;
  - stopped slots keep riding the batched step with a frozen position
    (``step_mask``): their cache writes land on a dead slot that the
    next admission overwrites, so no gather/compact is needed.

Resilience (PR 8) — every guard rides the compiled block; host logic
runs only at block boundaries, so the 1-dispatch-per-M-tokens structure
survives every failure mode:

  - ON-DEVICE OUTPUT GUARDS: per-slot fault flags carried in the scan
    (the serving analogue of the federation quarantine guard) trip on
    non-finite decode logits and on runaway token repetition; a tripped
    slot is frozen on device — the faulty token is never emitted — and
    the flag comes back in the block's single readback;
  - HOST WATCHDOG at block boundaries: slots past their completion
    deadline are cancelled via a ``cancel`` mask folded into the next
    block dispatch (``timed_out``), and slots making no progress for
    ``stall_blocks`` consecutive blocks are reclaimed as stuck;
  - RETRY WITH BACKOFF: faulted/stuck requests requeue through the
    scheduler's retry lane (re-prefilled from the prompt) up to
    ``max_attempts`` admissions, then land in the terminal ``failed``
    state;
  - ADMISSION CONTROL: the scheduler sheds queued requests past their
    TTFT deadline and beyond ``queue_cap`` at every boundary, bounding
    queue latency under overload (see ``serve.scheduler``);
  - SNAPSHOT/RESUME: ``snapshot()`` serialises the whole device state
    (cache pool, per-slot positions and budgets, RNG key, fault flags,
    global step counter) through ``repro.checkpoint`` with the
    scheduler in the JSON meta; ``ServeEngine.resume`` + a
    ``resume_serve()`` call continue a killed stream, bit-identical for
    already-admitted slots;
  - CHAOS: ``serve(fault_plan=...)`` injects a deterministic seeded
    fault schedule (``serve.faults``) — NaN-poisoned logits, silent
    slot freezes, host delays, and a simulated mid-stream crash.

``naive_generate`` keeps the legacy per-token loop alive as the oracle
and the benchmark baseline: one dispatch + one blocking argmax readback
per token, batches run head-of-line until every member finishes.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, read_meta, save_checkpoint
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serve import faults as F
from repro.serve.pool import init_pool_cache, scatter_slot
from repro.serve.scheduler import FifoScheduler, Request, RequestRecord

Array = jax.Array


@dataclass(frozen=True)
class ServeConfig:
    """Serving engine knobs.  ``max_new_tokens`` counts ALL generated
    tokens including the one sampled from the prefill logits.
    ``stop_token < 0`` disables early stopping.  ``temperature == 0`` is
    greedy.  ``attn_backend``: 'reference' (blockwise jnp), 'pallas'
    (``kernels.decode_attention``; interpret mode off-TPU), or 'auto'
    (pallas on TPU, reference elsewhere).

    SLO / resilience knobs (None / 0 disables each):

    - ``queue_cap``: max arrived-but-unadmitted requests held; newest
      beyond the cap are shed at block boundaries (bounded queue).
    - ``ttft_deadline_s`` / ``deadline_s``: default first-token and
      completion deadlines relative to arrival (per-request fields on
      ``Request`` override them).
    - ``max_attempts``: admissions per request before a faulted/stuck
      request becomes terminal ``failed``; ``retry_backoff_s`` delays
      each re-admission.
    - ``stall_blocks``: consecutive zero-progress blocks before the
      watchdog reclaims a slot as stuck (0 = watchdog off).
    - ``guard_nonfinite``: trip the on-device fault flag on non-finite
      decode logits instead of emitting a garbage token.
    - ``max_repeat``: trip the fault flag after this many CONSECUTIVE
      identical tokens from one slot (0 = off).
    """
    n_slots: int = 8
    cache_len: int = 128
    block_steps: int = 8
    max_new_tokens: int = 32
    stop_token: int = -1
    temperature: float = 0.0
    seed: int = 0
    attn_backend: str = "reference"
    queue_cap: Optional[int] = None
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None
    max_attempts: int = 2
    retry_backoff_s: float = 0.0
    stall_blocks: int = 0
    guard_nonfinite: bool = True
    max_repeat: int = 0


def _resolve_backend(name: str):
    """-> (backend, interpret) for decode_step_slots."""
    on_tpu = jax.default_backend() == "tpu"
    if name == "auto":
        return ("pallas", False) if on_tpu else ("reference", False)
    if name == "pallas":
        return "pallas", not on_tpu
    return "reference", False


class ServeEngine:
    """Continuous-batching engine for one model family.

    Usage::

        eng = ServeEngine(params, cfg, ServeConfig(n_slots=8))
        records = eng.serve(requests)        # scheduler.Request list
        records[rid].tokens                  # generated ids, stop incl.
        records[rid].state                   # terminal state (see
                                             # scheduler.TERMINAL_STATES)

    ``eng.stats`` counts compiled-call dispatches and blocking host
    readbacks by kind; the benchmark derives dispatches-per-token and
    host-syncs-per-token from it instead of asserting constants.
    """

    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig,
                 rt: Optional[T.Runtime] = None):
        if scfg.n_slots <= 0:
            raise ValueError(f"n_slots must be positive, got "
                             f"{scfg.n_slots}")
        if cfg.sliding_window:
            eff = min(scfg.cache_len, cfg.sliding_window)
            if eff < cfg.sliding_window:
                raise ValueError(
                    f"cache_len {scfg.cache_len} smaller than the sliding "
                    f"window {cfg.sliding_window}: the pool ring would not "
                    f"match prefill's ring packing")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.rt = rt or T.Runtime()
        self._backend, self._interpret = _resolve_backend(scfg.attn_backend)
        self.state = self._init_state()
        self._block_fns: Dict[Optional[F.FaultPlan], callable] = {}
        self._admit = jax.jit(self._admit_impl, donate_argnums=(1,))
        self._resume_sched: Optional[FifoScheduler] = None
        self._blocks_done = 0
        self.stats = {"block_dispatches": 0, "block_syncs": 0,
                      "block_tokens": 0, "admit_dispatches": 0,
                      "request_reads": 0, "faults_detected": 0,
                      "stalls_detected": 0, "snapshot_writes": 0}

    # ------------------------------------------------------------------
    def _init_state(self) -> dict:
        s = self.scfg.n_slots
        return {
            "cache": init_pool_cache(self.cfg, s, self.scfg.cache_len,
                                     self.rt),
            "active": jnp.zeros((s,), bool),
            "stopped": jnp.ones((s,), bool),
            "last_tok": jnp.zeros((s, 1), jnp.int32),
            "n_emitted": jnp.zeros((s,), jnp.int32),
            "max_new": jnp.full((s,), self.scfg.max_new_tokens, jnp.int32),
            "key": jax.random.PRNGKey(self.scfg.seed),
            # resilience carry: per-slot fault flags (the serving
            # quarantine guard), consecutive-repeat run lengths, and the
            # GLOBAL decode-step counter the chaos schedule indexes
            "fault": jnp.zeros((s,), bool),
            "rep_run": jnp.zeros((s,), jnp.int32),
            "t": jnp.zeros((), jnp.int32),
        }

    def _sample(self, logits: Array, key: Array) -> Array:
        """(S, V) float logits -> (S,) int32 next tokens, on device."""
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.scfg.temperature,
            axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def _admit_impl(self, params, state: dict, batch: dict, key: Array,
                    max_new: Array, slot: Array):
        """Prefill + first-token sampling + slot scatter, fused into ONE
        compiled call per admission (compiled once per prompt length).
        The first token lands in ``last_tok[slot]``; the host reads it
        lazily — admission costs zero blocking syncs.  The slot's fault
        flag and repeat counter reset here; non-finite PREFILL logits
        set the flag immediately so the first block boundary retries
        instead of streaming garbage."""
        logits, req_cache = T.prefill(params, batch, self.cfg, self.rt,
                                      cache_len=self.scfg.cache_len)
        last = logits[:, -1, :]
        first = self._sample(last, key)[0]
        stop = self.scfg.stop_token
        bad0 = (~jnp.isfinite(last.astype(jnp.float32)).all()
                if self.scfg.guard_nonfinite else jnp.asarray(False))
        first_stopped = bad0 | (max_new <= 1) | (first == stop if stop >= 0
                                                 else False)
        cache = scatter_slot(state["cache"], req_cache, slot)
        return dict(
            state,
            cache=cache,
            active=state["active"].at[slot].set(True),
            stopped=state["stopped"].at[slot].set(first_stopped),
            last_tok=state["last_tok"].at[slot, 0].set(first),
            n_emitted=state["n_emitted"].at[slot].set(1),
            max_new=state["max_new"].at[slot].set(max_new),
            fault=state["fault"].at[slot].set(bad0),
            rep_run=state["rep_run"].at[slot].set(0),
        )

    def _block_impl(self, plan: Optional[F.FaultPlan], params, state: dict,
                    cancel: Array):
        """M fused decode steps: sampling, stop accounting, and the
        output guards all in the scan carry; one (M, S) token buffer
        comes back per dispatch.  ``cancel`` (S,) bool freezes
        deadline-expired slots on device without an extra dispatch.
        ``plan`` is a STATIC chaos schedule (None = clean)."""
        stop = self.scfg.stop_token
        max_rep = self.scfg.max_repeat
        n_slots = self.scfg.n_slots
        state = dict(state, stopped=state["stopped"] | cancel)

        def step(st, _):
            running = st["active"] & ~st["stopped"]
            frozen = F.freeze_mask(plan, st["t"], n_slots)
            if frozen is not None:
                running = running & ~frozen
            logits, cache = T.decode_step_slots(
                params, st["cache"], {"tokens": st["last_tok"]}, self.cfg,
                self.rt, step_mask=running, attn_backend=self._backend,
                attn_interpret=self._interpret)
            lg = F.poison_logits(plan, st["t"], logits[:, 0, :])
            key, sub = jax.random.split(st["key"])
            tok = self._sample(lg, sub)
            # output guards: a tripped slot freezes and its token is
            # never emitted — the host retries from the prompt instead
            if self.scfg.guard_nonfinite:
                bad = running & ~jnp.isfinite(
                    lg.astype(jnp.float32)).all(axis=-1)
            else:
                bad = jnp.zeros_like(running)
            ok = running & ~bad
            same = tok == st["last_tok"][:, 0]
            rep_run = jnp.where(ok, jnp.where(same, st["rep_run"] + 1, 0),
                                st["rep_run"])
            if max_rep > 0:
                bad = bad | (ok & (rep_run >= max_rep))
            good = running & ~bad
            tok = jnp.where(good, tok, st["last_tok"][:, 0])
            n_emitted = st["n_emitted"] + good.astype(jnp.int32)
            hit_stop = (tok == stop) if stop >= 0 else jnp.zeros_like(good)
            exhausted = n_emitted >= st["max_new"]
            stopped = st["stopped"] | (good & (hit_stop | exhausted)) | bad
            st = dict(st, cache=cache, last_tok=tok[:, None],
                      n_emitted=n_emitted, stopped=stopped, key=key,
                      fault=st["fault"] | bad, rep_run=rep_run,
                      t=st["t"] + 1)
            return st, (tok, good)

        state, (toks, emitted) = jax.lax.scan(
            step, state, None, length=self.scfg.block_steps)
        return state, toks, emitted

    def _get_block(self, plan: Optional[F.FaultPlan]):
        """One compilation per distinct device-visible fault schedule;
        host-only plans (delays/crash) share the clean compilation."""
        key = None if plan is None or plan.device_silent else plan
        if key not in self._block_fns:
            self._block_fns[key] = jax.jit(
                partial(self._block_impl, key), donate_argnums=(1,))
        return self._block_fns[key]

    # ------------------------------------------------------------------
    def _admit_request(self, req: Request, rec: RequestRecord,
                       sync_ttft: bool, now) -> None:
        scfg = self.scfg
        max_new = req.max_new if req.max_new is not None \
            else scfg.max_new_tokens
        if not self.cfg.sliding_window and self.cfg.family != "ssm":
            need = len(req.tokens) + max_new + 1
            if need > scfg.cache_len:
                raise ValueError(f"request {req.rid}: prompt+max_new "
                                 f"{need} exceeds cache_len {scfg.cache_len}")
        batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None]}
        for name, arr in req.extras:
            batch[name] = jnp.asarray(arr)[None]
        key = jax.random.fold_in(jax.random.PRNGKey(scfg.seed + 1), req.rid)
        self.state = self._admit(self.params, self.state, batch, key,
                                 jnp.asarray(max_new, jnp.int32),
                                 jnp.asarray(rec.slot, jnp.int32))
        self.stats["admit_dispatches"] += 1
        first = self.state["last_tok"][rec.slot, 0]
        rec.tokens.append(first)           # device scalar; resolved lazily
        if sync_ttft:
            first.block_until_ready()
            self.stats["request_reads"] += 1
            rec.first_token_s = now()

    def serve(self, requests: List[Request], *, sync_ttft: bool = False,
              fault_plan: Optional[F.FaultPlan] = None,
              snapshot_path: Optional[str] = None,
              snapshot_every_blocks: int = 0) -> Dict[int, RequestRecord]:
        """Run a request stream to completion with continuous batching.

        Admission happens between decode blocks: arrived requests fill
        free slots (prefill + scatter), then one fused M-step block runs
        and its (M, S) token buffer is read back — the only blocking
        host sync in the decode path.  With ``sync_ttft`` the engine
        additionally blocks on each request's first token to timestamp
        TTFT (a per-REQUEST sync, used by the latency benchmark).

        ``fault_plan`` injects the chaos schedule (``serve.faults``);
        ``snapshot_path`` + ``snapshot_every_blocks=N`` write a
        restore-compatible serve snapshot every N blocks, so a crash —
        real or simulated — loses at most N blocks of decode work.
        """
        scfg = self.scfg
        sched = FifoScheduler(requests, scfg.n_slots,
                              queue_cap=scfg.queue_cap,
                              ttft_deadline_s=scfg.ttft_deadline_s,
                              deadline_s=scfg.deadline_s)
        self._blocks_done = 0        # block indices are per-stream; only
        # resume_serve continues a restored counter (chaos schedules and
        # snapshot steps index it)
        return self._run(sched, sync_ttft=sync_ttft, fault_plan=fault_plan,
                         snapshot_path=snapshot_path,
                         snapshot_every_blocks=snapshot_every_blocks)

    def resume_serve(self, *, sync_ttft: bool = False,
                     fault_plan: Optional[F.FaultPlan] = None,
                     snapshot_path: Optional[str] = None,
                     snapshot_every_blocks: int = 0
                     ) -> Dict[int, RequestRecord]:
        """Continue the stream restored by :meth:`resume`: unfinished
        requests run to a terminal state (already-admitted slots resume
        bit-identically from the snapshot's device state).  Wall-clock
        SLO timestamps restart from the resume instant — crash recovery
        prioritises completing work over latency bookkeeping."""
        if self._resume_sched is None:
            raise RuntimeError("no restored stream: construct the engine "
                               "with ServeEngine.resume(path, ...) first")
        sched, self._resume_sched = self._resume_sched, None
        return self._run(sched, sync_ttft=sync_ttft, fault_plan=fault_plan,
                         snapshot_path=snapshot_path,
                         snapshot_every_blocks=snapshot_every_blocks)

    def _run(self, sched: FifoScheduler, *, sync_ttft: bool,
             fault_plan: Optional[F.FaultPlan],
             snapshot_path: Optional[str],
             snapshot_every_blocks: int) -> Dict[int, RequestRecord]:
        scfg = self.scfg
        block = self._get_block(fault_plan)
        self._sched = sched
        stall = [0] * scfg.n_slots
        t0 = time.perf_counter()

        def now():
            return time.perf_counter() - t0

        while not sched.done:
            sched.shed_expired(now())
            while sched.admissible(now()):
                req, slot = sched.pop(now())
                stall[slot] = 0
                self._admit_request(req, sched.records[req.rid],
                                    sync_ttft, now)
                # a request that stops at its first token never decodes
                if (req.max_new or scfg.max_new_tokens) <= 1:
                    rec = sched.records[req.rid]
                    if rec.first_token_s is None:
                        rec.first_token_s = now()
                    sched.release(slot, now())
            busy = [s for s, rid in enumerate(sched.slot_rid)
                    if rid is not None]
            if not busy:
                nr = sched.next_ready()
                if nr is None:
                    break
                wait = nr - now()
                if wait > 0:
                    time.sleep(wait)
                continue
            if (fault_plan is not None and fault_plan.delay_s > 0
                    and self._blocks_done in fault_plan.delay_blocks):
                time.sleep(fault_plan.delay_s)
            # watchdog, part 1: deadline-expired slots are cancelled ON
            # DEVICE by the block dispatch itself (no extra dispatch)
            cancel = np.zeros((scfg.n_slots,), bool)
            t_check = now()
            for s in busy:
                if t_check > sched.abs_deadline(sched.slot_rid[s]):
                    cancel[s] = True
            self.state, toks, emitted = block(self.params, self.state,
                                              jnp.asarray(cancel))
            self.stats["block_dispatches"] += 1
            # ONE readback per block: tokens, emission mask, stop and
            # fault flags
            toks_h, emitted_h, stopped_h, fault_h = jax.device_get(
                (toks, emitted, self.state["stopped"],
                 self.state["fault"]))
            self.stats["block_syncs"] += 1
            t_block = now()
            for s in busy:
                rec = sched.records[sched.slot_rid[s]]
                if cancel[s]:
                    sched.release(s, t_block, state="timed_out")
                    continue
                new = toks_h[emitted_h[:, s], s]
                rec.tokens.extend(int(t) for t in new)
                self.stats["block_tokens"] += int(emitted_h[:, s].sum())
                if rec.first_token_s is None and len(rec.tokens) > 0:
                    rec.first_token_s = t_block
                if fault_h[s]:
                    rec.faults += 1
                    self.stats["faults_detected"] += 1
                    self._retry_or_fail(sched, s, t_block)
                elif stopped_h[s]:
                    sched.release(s, t_block)
                elif scfg.stall_blocks > 0 and not emitted_h[:, s].any():
                    # watchdog, part 2: a live slot that emitted nothing
                    stall[s] += 1
                    if stall[s] >= scfg.stall_blocks:
                        stall[s] = 0
                        self.stats["stalls_detected"] += 1
                        self._retry_or_fail(sched, s, t_block)
                else:
                    stall[s] = 0
            self._blocks_done += 1
            if (snapshot_path and snapshot_every_blocks > 0
                    and self._blocks_done % snapshot_every_blocks == 0):
                self.snapshot(snapshot_path, sched)
            if (fault_plan is not None
                    and fault_plan.crash_after_block >= 0
                    and self._blocks_done - 1
                    == fault_plan.crash_after_block):
                raise F.SimulatedCrash(
                    f"fault plan killed the engine after block "
                    f"{fault_plan.crash_after_block}"
                    + (f"; resume from {snapshot_path!r}"
                       if snapshot_path else ""))
        for rec in sched.records.values():      # resolve lazy first tokens
            rec.tokens = [int(t) for t in rec.tokens]
        return sched.records

    def _retry_or_fail(self, sched: FifoScheduler, slot: int,
                       now_s: float) -> None:
        """Reclaim a faulted/stuck slot: requeue with backoff while the
        attempt budget lasts, else terminal ``failed``."""
        rid = sched.slot_rid[slot]
        if sched.records[rid].attempts < self.scfg.max_attempts:
            sched.requeue(slot, now_s + self.scfg.retry_backoff_s)
        else:
            sched.release(slot, now_s, state="failed")

    # ----------------------------------------------------- persistence
    def snapshot(self, path: str,
                 sched: Optional[FifoScheduler] = None) -> None:
        """Serialise the full serve state through ``repro.checkpoint``:
        the device pool (cache, per-slot positions, budgets, RNG key,
        fault flags, global step counter) as the checkpoint tree and the
        scheduler + ``ServeConfig`` in the JSON meta.  Atomic like every
        checkpoint write; a crash mid-save never corrupts the previous
        snapshot."""
        sched = sched if sched is not None else self._sched
        for rec in sched.records.values():      # resolve lazy device scalars
            rec.tokens = [int(t) for t in rec.tokens]
        meta = {
            "kind": "serve_snapshot",
            "serve_config": dataclasses.asdict(self.scfg),
            "model_family": self.cfg.family,
            "scheduler": sched.to_meta(),
            "blocks_done": self._blocks_done,
        }
        save_checkpoint(path, jax.device_get(self.state),
                        step=self._blocks_done, meta=meta)
        self.stats["snapshot_writes"] += 1

    @classmethod
    def resume(cls, path: str, params, cfg: ModelConfig,
               rt: Optional[T.Runtime] = None) -> "ServeEngine":
        """Rebuild an engine from a serve snapshot (``CheckpointError``
        on a truncated/corrupt file, ``ValueError`` on a snapshot from a
        different serve/model configuration).  Follow with
        :meth:`resume_serve` to run the restored stream to completion."""
        meta = read_meta(path)
        if meta.get("kind") != "serve_snapshot":
            raise ValueError(f"{path!r} is not a serve snapshot "
                             f"(kind={meta.get('kind')!r})")
        if meta["model_family"] != cfg.family:
            raise ValueError(
                f"snapshot {path!r} was taken from a {meta['model_family']!r}"
                f" model, cannot restore into {cfg.family!r}")
        scfg = ServeConfig(**meta["serve_config"])
        eng = cls(params, cfg, scfg, rt)
        state, step = load_checkpoint(path, eng.state)
        eng.state = state
        eng._blocks_done = int(step)
        eng._resume_sched = FifoScheduler.from_meta(meta["scheduler"])
        return eng


# ======================================================================
# Module-level jits (cfg / rt / cache_len static) so repeated
# naive_generate calls — warm-up then timed — share compilations.
@partial(jax.jit, static_argnums=(2, 3, 4))
def _naive_prefill(params, batch, cfg, rt, cache_len):
    return T.prefill(params, batch, cfg, rt, cache_len=cache_len)


@partial(jax.jit, static_argnums=(3, 4))
def _naive_decode(params, cache, tok, cfg, rt):
    return T.decode_step(params, cache, {"tokens": tok}, cfg, rt)


def naive_generate(params, cfg: ModelConfig, requests: List[Request],
                   scfg: ServeConfig, rt: Optional[T.Runtime] = None,
                   stats: Optional[dict] = None) -> Dict[int, RequestRecord]:
    """The legacy per-token loop, kept as oracle + benchmark baseline.

    Requests run in arrival order in fixed batches of ``n_slots`` (all
    prompts in a batch must share one length — the loop cannot pack);
    every decoded token pays one jit dispatch plus one blocking host
    readback (argmax + stop check on the host), and a batch runs until
    EVERY member finishes (head-of-line blocking), exactly the structure
    the continuous-batching engine removes.  Greedy only.
    """
    rt = rt or T.Runtime()
    stats = stats if stats is not None else {}
    stats.setdefault("decode_dispatches", 0)
    stats.setdefault("host_syncs", 0)
    stats.setdefault("decode_tokens", 0)
    stats.setdefault("prefill_dispatches", 0)

    def prefill_j(p, b):
        return _naive_prefill(p, b, cfg, rt, scfg.cache_len)

    def decode_j(p, c, t):
        return _naive_decode(p, c, t, cfg, rt)

    records = {r.rid: RequestRecord(request=r) for r in requests}
    order = sorted(requests, key=lambda r: r.arrival_s)
    t0 = time.perf_counter()
    for i in range(0, len(order), scfg.n_slots):
        group = order[i:i + scfg.n_slots]
        plens = {len(r.tokens) for r in group}
        assert len(plens) == 1, "naive baseline needs equal prompt lengths"
        batch = {"tokens": jnp.asarray([r.tokens for r in group],
                                       jnp.int32)}
        logits, cache = prefill_j(params, batch)
        stats["prefill_dispatches"] += 1
        tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                         np.int32)                       # host sync
        stats["host_syncs"] += 1
        t_first = time.perf_counter() - t0
        budgets = [r.max_new if r.max_new is not None
                   else scfg.max_new_tokens for r in group]
        outs = [[int(t)] for t in tok]
        done = [budgets[j] <= 1 or
                (scfg.stop_token >= 0 and int(tok[j]) == scfg.stop_token)
                for j in range(len(group))]
        for j, r in enumerate(group):
            records[r.rid].first_token_s = t_first
            records[r.rid].slot = j
        # head-of-line: the whole batch keeps stepping until ALL are done
        dev_tok = jnp.asarray(tok)[:, None]
        while not all(done):
            logits, cache = decode_j(params, cache, dev_tok)
            stats["decode_dispatches"] += 1
            tok = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1),
                             np.int32)                   # per-token sync
            stats["host_syncs"] += 1
            for j in range(len(group)):
                if done[j]:
                    continue
                outs[j].append(int(tok[j]))
                stats["decode_tokens"] += 1
                if ((scfg.stop_token >= 0 and int(tok[j]) == scfg.stop_token)
                        or len(outs[j]) >= budgets[j]):
                    done[j] = True
            dev_tok = jnp.asarray(tok)[:, None]
        t_done = time.perf_counter() - t0
        for j, r in enumerate(group):
            records[r.rid].tokens = outs[j]
            records[r.rid].finished_s = t_done
            records[r.rid].state = "completed"
    return records
