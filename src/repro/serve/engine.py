"""Continuous-batching decode engine: fused decode blocks over a donated
slot-stacked cache pool.

The legacy loop (``examples/serve_decode.py``) pays one jit dispatch plus
a blocking host readback per decoded token and head-of-line blocks the
whole batch on its slowest sequence.  This engine applies the round
engine's idioms to serving:

  - the S request slots live in ONE slot-stacked cache pool
    (``serve.pool``) with per-slot positions, ``active`` / ``stopped``
    masks, a per-slot token budget, and the last sampled token — all
    device-resident and DONATED to the compiled step, so pool buffers
    alias across blocks like round state aliases across rounds;
  - ``M = block_steps`` decode steps are fused into one jitted
    ``lax.scan`` (``_block_fn``): greedy/temperature sampling and
    stop-token accounting run ON DEVICE in the carry, tokens accumulate
    into an (M, S) device buffer, and the host pays exactly one dispatch
    and one readback per M tokens-per-slot — the serving analogue of
    ``RoundEngine.run_block``;
  - new requests are admitted MID-DECODE: prefill runs as its own
    compiled call (per prompt length), and the resulting single-request
    cache is scattered into a free slot (``scatter_slot``) without
    touching in-flight slots or recompiling anything;
  - stopped slots keep riding the batched step with a frozen position
    (``step_mask``): their cache writes land on a dead slot that the
    next admission overwrites, so no gather/compact is needed.

``naive_generate`` keeps the legacy per-token loop alive as the oracle
and the benchmark baseline: one dispatch + one blocking argmax readback
per token, batches run head-of-line until every member finishes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serve.pool import init_pool_cache, scatter_slot
from repro.serve.scheduler import FifoScheduler, Request, RequestRecord

Array = jax.Array


@dataclass(frozen=True)
class ServeConfig:
    """Serving engine knobs.  ``max_new_tokens`` counts ALL generated
    tokens including the one sampled from the prefill logits.
    ``stop_token < 0`` disables early stopping.  ``temperature == 0`` is
    greedy.  ``attn_backend``: 'reference' (blockwise jnp), 'pallas'
    (``kernels.decode_attention``; interpret mode off-TPU), or 'auto'
    (pallas on TPU, reference elsewhere)."""
    n_slots: int = 8
    cache_len: int = 128
    block_steps: int = 8
    max_new_tokens: int = 32
    stop_token: int = -1
    temperature: float = 0.0
    seed: int = 0
    attn_backend: str = "reference"


def _resolve_backend(name: str):
    """-> (backend, interpret) for decode_step_slots."""
    on_tpu = jax.default_backend() == "tpu"
    if name == "auto":
        return ("pallas", False) if on_tpu else ("reference", False)
    if name == "pallas":
        return "pallas", not on_tpu
    return "reference", False


class ServeEngine:
    """Continuous-batching engine for one model family.

    Usage::

        eng = ServeEngine(params, cfg, ServeConfig(n_slots=8))
        records = eng.serve(requests)        # scheduler.Request list
        records[rid].tokens                  # generated ids, stop incl.

    ``eng.stats`` counts compiled-call dispatches and blocking host
    readbacks by kind; the benchmark derives dispatches-per-token and
    host-syncs-per-token from it instead of asserting constants.
    """

    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig,
                 rt: Optional[T.Runtime] = None):
        if cfg.sliding_window:
            eff = min(scfg.cache_len, cfg.sliding_window)
            if eff < cfg.sliding_window:
                raise ValueError(
                    f"cache_len {scfg.cache_len} smaller than the sliding "
                    f"window {cfg.sliding_window}: the pool ring would not "
                    f"match prefill's ring packing")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.rt = rt or T.Runtime()
        self._backend, self._interpret = _resolve_backend(scfg.attn_backend)
        self.state = self._init_state()
        self._block = jax.jit(self._block_impl, donate_argnums=(1,))
        self._admit = jax.jit(self._admit_impl, donate_argnums=(1,))
        self.stats = {"block_dispatches": 0, "block_syncs": 0,
                      "block_tokens": 0, "admit_dispatches": 0,
                      "request_reads": 0}

    # ------------------------------------------------------------------
    def _init_state(self) -> dict:
        s = self.scfg.n_slots
        return {
            "cache": init_pool_cache(self.cfg, s, self.scfg.cache_len,
                                     self.rt),
            "active": jnp.zeros((s,), bool),
            "stopped": jnp.ones((s,), bool),
            "last_tok": jnp.zeros((s, 1), jnp.int32),
            "n_emitted": jnp.zeros((s,), jnp.int32),
            "max_new": jnp.full((s,), self.scfg.max_new_tokens, jnp.int32),
            "key": jax.random.PRNGKey(self.scfg.seed),
        }

    def _sample(self, logits: Array, key: Array) -> Array:
        """(S, V) float logits -> (S,) int32 next tokens, on device."""
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.scfg.temperature,
            axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def _admit_impl(self, params, state: dict, batch: dict, key: Array,
                    max_new: Array, slot: Array):
        """Prefill + first-token sampling + slot scatter, fused into ONE
        compiled call per admission (compiled once per prompt length).
        The first token lands in ``last_tok[slot]``; the host reads it
        lazily — admission costs zero blocking syncs."""
        logits, req_cache = T.prefill(params, batch, self.cfg, self.rt,
                                      cache_len=self.scfg.cache_len)
        first = self._sample(logits[:, -1, :], key)[0]
        stop = self.scfg.stop_token
        first_stopped = (max_new <= 1) | (first == stop if stop >= 0
                                          else False)
        cache = scatter_slot(state["cache"], req_cache, slot)
        return dict(
            state,
            cache=cache,
            active=state["active"].at[slot].set(True),
            stopped=state["stopped"].at[slot].set(first_stopped),
            last_tok=state["last_tok"].at[slot, 0].set(first),
            n_emitted=state["n_emitted"].at[slot].set(1),
            max_new=state["max_new"].at[slot].set(max_new),
        )

    def _block_impl(self, params, state: dict):
        """M fused decode steps: sampling + stop accounting in the scan
        carry; one (M, S) token buffer comes back per dispatch."""
        stop = self.scfg.stop_token

        def step(st, _):
            running = st["active"] & ~st["stopped"]
            logits, cache = T.decode_step_slots(
                params, st["cache"], {"tokens": st["last_tok"]}, self.cfg,
                self.rt, step_mask=running, attn_backend=self._backend,
                attn_interpret=self._interpret)
            key, sub = jax.random.split(st["key"])
            tok = self._sample(logits[:, 0, :], sub)
            tok = jnp.where(running, tok, st["last_tok"][:, 0])
            n_emitted = st["n_emitted"] + running.astype(jnp.int32)
            hit_stop = (tok == stop) if stop >= 0 else jnp.zeros_like(running)
            exhausted = n_emitted >= st["max_new"]
            stopped = st["stopped"] | (running & (hit_stop | exhausted))
            st = dict(st, cache=cache, last_tok=tok[:, None],
                      n_emitted=n_emitted, stopped=stopped, key=key)
            return st, (tok, running)

        state, (toks, emitted) = jax.lax.scan(
            step, state, None, length=self.scfg.block_steps)
        return state, toks, emitted

    # ------------------------------------------------------------------
    def _admit_request(self, req: Request, rec: RequestRecord,
                       sync_ttft: bool, now) -> None:
        scfg = self.scfg
        max_new = req.max_new if req.max_new is not None \
            else scfg.max_new_tokens
        if not self.cfg.sliding_window and self.cfg.family != "ssm":
            need = len(req.tokens) + max_new + 1
            if need > scfg.cache_len:
                raise ValueError(f"request {req.rid}: prompt+max_new "
                                 f"{need} exceeds cache_len {scfg.cache_len}")
        batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None]}
        for name, arr in req.extras:
            batch[name] = jnp.asarray(arr)[None]
        key = jax.random.fold_in(jax.random.PRNGKey(scfg.seed + 1), req.rid)
        self.state = self._admit(self.params, self.state, batch, key,
                                 jnp.asarray(max_new, jnp.int32),
                                 jnp.asarray(rec.slot, jnp.int32))
        self.stats["admit_dispatches"] += 1
        first = self.state["last_tok"][rec.slot, 0]
        rec.tokens.append(first)           # device scalar; resolved lazily
        if sync_ttft:
            first.block_until_ready()
            self.stats["request_reads"] += 1
            rec.first_token_s = now()

    def serve(self, requests: List[Request], *,
              sync_ttft: bool = False) -> Dict[int, RequestRecord]:
        """Run a request stream to completion with continuous batching.

        Admission happens between decode blocks: arrived requests fill
        free slots (prefill + scatter), then one fused M-step block runs
        and its (M, S) token buffer is read back — the only blocking
        host sync in the decode path.  With ``sync_ttft`` the engine
        additionally blocks on each request's first token to timestamp
        TTFT (a per-REQUEST sync, used by the latency benchmark).
        """
        scfg = self.scfg
        sched = FifoScheduler(requests, scfg.n_slots)
        t0 = time.perf_counter()

        def now():
            return time.perf_counter() - t0

        while not sched.done:
            while sched.admissible(now()):
                req, slot = sched.pop(now())
                self._admit_request(req, sched.records[req.rid],
                                    sync_ttft, now)
                # a request that stops at its first token never decodes
                if (req.max_new or scfg.max_new_tokens) <= 1:
                    rec = sched.records[req.rid]
                    if rec.first_token_s is None:
                        rec.first_token_s = now()
                    sched.release(slot, now())
            busy = [s for s, rid in enumerate(sched.slot_rid)
                    if rid is not None]
            if not busy:
                na = sched.next_arrival()
                if na is None:
                    break
                wait = na - now()
                if wait > 0:
                    time.sleep(wait)
                continue
            self.state, toks, emitted = self._block(self.params, self.state)
            self.stats["block_dispatches"] += 1
            # ONE readback per block: tokens, emission mask, stop flags
            toks_h, emitted_h, stopped_h = jax.device_get(
                (toks, emitted, self.state["stopped"]))
            self.stats["block_syncs"] += 1
            t_block = now()
            for s in busy:
                rec = sched.records[sched.slot_rid[s]]
                new = toks_h[emitted_h[:, s], s]
                rec.tokens.extend(int(t) for t in new)
                self.stats["block_tokens"] += int(emitted_h[:, s].sum())
                if rec.first_token_s is None:
                    rec.first_token_s = t_block
                if stopped_h[s]:
                    sched.release(s, t_block)
        for rec in sched.records.values():      # resolve lazy first tokens
            rec.tokens = [int(t) for t in rec.tokens]
        return sched.records


# ======================================================================
# Module-level jits (cfg / rt / cache_len static) so repeated
# naive_generate calls — warm-up then timed — share compilations.
@partial(jax.jit, static_argnums=(2, 3, 4))
def _naive_prefill(params, batch, cfg, rt, cache_len):
    return T.prefill(params, batch, cfg, rt, cache_len=cache_len)


@partial(jax.jit, static_argnums=(3, 4))
def _naive_decode(params, cache, tok, cfg, rt):
    return T.decode_step(params, cache, {"tokens": tok}, cfg, rt)


def naive_generate(params, cfg: ModelConfig, requests: List[Request],
                   scfg: ServeConfig, rt: Optional[T.Runtime] = None,
                   stats: Optional[dict] = None) -> Dict[int, RequestRecord]:
    """The legacy per-token loop, kept as oracle + benchmark baseline.

    Requests run in arrival order in fixed batches of ``n_slots`` (all
    prompts in a batch must share one length — the loop cannot pack);
    every decoded token pays one jit dispatch plus one blocking host
    readback (argmax + stop check on the host), and a batch runs until
    EVERY member finishes (head-of-line blocking), exactly the structure
    the continuous-batching engine removes.  Greedy only.
    """
    rt = rt or T.Runtime()
    stats = stats if stats is not None else {}
    stats.setdefault("decode_dispatches", 0)
    stats.setdefault("host_syncs", 0)
    stats.setdefault("decode_tokens", 0)
    stats.setdefault("prefill_dispatches", 0)

    def prefill_j(p, b):
        return _naive_prefill(p, b, cfg, rt, scfg.cache_len)

    def decode_j(p, c, t):
        return _naive_decode(p, c, t, cfg, rt)

    records = {r.rid: RequestRecord(request=r) for r in requests}
    order = sorted(requests, key=lambda r: r.arrival_s)
    t0 = time.perf_counter()
    for i in range(0, len(order), scfg.n_slots):
        group = order[i:i + scfg.n_slots]
        plens = {len(r.tokens) for r in group}
        assert len(plens) == 1, "naive baseline needs equal prompt lengths"
        batch = {"tokens": jnp.asarray([r.tokens for r in group],
                                       jnp.int32)}
        logits, cache = prefill_j(params, batch)
        stats["prefill_dispatches"] += 1
        tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                         np.int32)                       # host sync
        stats["host_syncs"] += 1
        t_first = time.perf_counter() - t0
        budgets = [r.max_new if r.max_new is not None
                   else scfg.max_new_tokens for r in group]
        outs = [[int(t)] for t in tok]
        done = [budgets[j] <= 1 or
                (scfg.stop_token >= 0 and int(tok[j]) == scfg.stop_token)
                for j in range(len(group))]
        for j, r in enumerate(group):
            records[r.rid].first_token_s = t_first
            records[r.rid].slot = j
        # head-of-line: the whole batch keeps stepping until ALL are done
        dev_tok = jnp.asarray(tok)[:, None]
        while not all(done):
            logits, cache = decode_j(params, cache, dev_tok)
            stats["decode_dispatches"] += 1
            tok = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1),
                             np.int32)                   # per-token sync
            stats["host_syncs"] += 1
            for j in range(len(group)):
                if done[j]:
                    continue
                outs[j].append(int(tok[j]))
                stats["decode_tokens"] += 1
                if ((scfg.stop_token >= 0 and int(tok[j]) == scfg.stop_token)
                        or len(outs[j]) >= budgets[j]):
                    done[j] = True
            dev_tok = jnp.asarray(tok)[:, None]
        t_done = time.perf_counter() - t0
        for j, r in enumerate(group):
            records[r.rid].tokens = outs[j]
            records[r.rid].finished_s = t_done
    return records
