"""Deterministic chaos-injection harness for the serving engine.

A :class:`FaultPlan` is a STATIC, seeded fault schedule baked into the
compiled decode block (the plan's tuples are trace-time constants, so
each distinct plan compiles once and replays bit-identically):

  - ``nan_steps`` poisons the decode logits of the chosen slots with NaN
    on the chosen GLOBAL decode-step indices — the engine carries a
    step counter ``t`` in the scan, so the schedule is deterministic
    across blocks, retries, and even a snapshot/resume (``t`` rides the
    checkpoint);
  - ``force_steps`` biases the logits so one fixed token wins — finite
    values, so the non-finite guard stays silent and only the
    runaway-repetition guard can catch it;
  - ``freeze_steps`` silently halts the chosen slots (no token emitted,
    no cache advance, NOT stopped) — the device-side "stuck slot" the
    host watchdog must notice, complementing ``delay_blocks``;
  - ``delay_blocks`` + ``delay_s`` sleep the HOST before dispatching the
    chosen block indices (slow-host / slow-interconnect simulation);
  - ``crash_after_block`` raises :class:`SimulatedCrash` after the
    results of that block index have been consumed (and after any due
    snapshot), simulating an engine process dying mid-stream.

Everything device-side rides the fused block: injection is a masked
``where`` on the logits / run mask inside the scan, so the chaos path
keeps the one-dispatch-per-M-tokens structure it is trying to break.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class SimulatedCrash(RuntimeError):
    """The fault plan killed the engine mid-stream.  The serve loop has
    already written any due snapshot; recover with
    ``ServeEngine.resume(path, ...)`` + ``resume_serve()``."""


@dataclass(frozen=True)
class FaultPlan:
    """Seeded fault schedule.  Step fields index the engine's GLOBAL
    decode-step counter; block fields index dispatched decode blocks
    within one serve run.  Empty slot tuples mean "every slot"."""
    nan_steps: Tuple[int, ...] = ()
    nan_slots: Tuple[int, ...] = ()
    force_steps: Tuple[int, ...] = ()
    force_slots: Tuple[int, ...] = ()
    force_token: int = 0
    freeze_steps: Tuple[int, ...] = ()
    freeze_slots: Tuple[int, ...] = ()
    delay_blocks: Tuple[int, ...] = ()
    delay_s: float = 0.0
    crash_after_block: int = -1

    @property
    def device_silent(self) -> bool:
        """True when the plan injects nothing into the compiled block
        (host-side delays/crash only) — the engine then reuses the
        fault-free compilation."""
        return not (self.nan_steps or self.force_steps or self.freeze_steps)


def seeded_plan(seed: int, *, n_steps: int, n_slots: int,
                nan_rate: float = 0.0, freeze_rate: float = 0.0,
                freeze_span: int = 2, delay_rate: float = 0.0,
                delay_s: float = 0.0,
                crash_after_block: int = -1) -> FaultPlan:
    """A deterministic seeded schedule over ``n_steps`` decode steps:
    each step is NaN-poisoned with ``nan_rate`` (one victim slot drawn
    per event), starts a ``freeze_span``-step freeze with
    ``freeze_rate``, and each block is host-delayed with
    ``delay_rate``."""
    rng = random.Random(seed)
    nan_steps, nan_slots = [], set()
    freeze_steps = []
    for t in range(n_steps):
        if nan_rate > 0 and rng.random() < nan_rate:
            nan_steps.append(t)
            nan_slots.add(rng.randrange(n_slots))
        if freeze_rate > 0 and rng.random() < freeze_rate:
            freeze_steps.extend(range(t, t + freeze_span))
    delay_blocks = tuple(b for b in range(max(1, n_steps))
                         if delay_rate > 0 and rng.random() < delay_rate)
    return FaultPlan(
        nan_steps=tuple(nan_steps), nan_slots=tuple(sorted(nan_slots)),
        freeze_steps=tuple(sorted(set(freeze_steps))),
        freeze_slots=tuple(sorted(nan_slots)) or (0,),
        delay_blocks=delay_blocks, delay_s=delay_s,
        crash_after_block=crash_after_block)


# ----------------------------------------------------------- tracing
def _step_hit(t: Array, steps: Tuple[int, ...]) -> Array:
    """() bool: is the traced global step ``t`` in the static tuple?"""
    return (t == jnp.asarray(steps, jnp.int32)).any()


def _slot_mask(slots: Tuple[int, ...], n_slots: int) -> Array:
    if not slots:
        return jnp.ones((n_slots,), bool)
    return jnp.zeros((n_slots,), bool).at[jnp.asarray(slots)].set(True)


def poison_logits(plan: Optional[FaultPlan], t: Array,
                  logits: Array) -> Array:
    """Apply the plan's logit faults at global step ``t`` to (S, V)
    decode logits (identity when the plan is silent)."""
    if plan is None:
        return logits
    s = logits.shape[0]
    if plan.nan_steps:
        mask = _step_hit(t, plan.nan_steps) & _slot_mask(plan.nan_slots, s)
        logits = jnp.where(mask[:, None], jnp.nan, logits)
    if plan.force_steps:
        mask = _step_hit(t, plan.force_steps) \
            & _slot_mask(plan.force_slots, s)
        forced = jnp.where(
            jnp.arange(logits.shape[-1]) == plan.force_token,
            jnp.asarray(1e9, logits.dtype), jnp.asarray(-1e9, logits.dtype))
        logits = jnp.where(mask[:, None], forced, logits)
    return logits


def freeze_mask(plan: Optional[FaultPlan], t: Array,
                n_slots: int) -> Optional[Array]:
    """(S,) bool mask of slots silently frozen at global step ``t``
    (None when the plan never freezes — keeps the fault-free trace
    byte-identical)."""
    if plan is None or not plan.freeze_steps:
        return None
    return _step_hit(t, plan.freeze_steps) \
        & _slot_mask(plan.freeze_slots, n_slots)
