"""Slot-stacked decode cache pool.

The pool is the serving engine's analogue of the round engine's stacked
node state: a fixed set of S request slots whose per-layer caches
(ring-buffer SWA K/V, MLA latents, SSM / RG-LRU states — whatever
``models.transformer.init_cache`` emits for the family) are stacked on
the leading slot axis, with one per-slot position vector ``len`` (S,)
instead of the single-batch scalar.  Because slot membership is a data
index, not a shape, admitting a new request is a SCATTER of its
prefilled single-request cache into a free slot — no recompilation, no
restart of the in-flight batch.

``scatter_slot`` routes every leaf by its batch-axis position: stacked
per-layer leaves are (L, S, ...) (axis 1), hybrid tail blocks are
unstacked (S, ...) (axis 0).  The prefilled request cache has the same
structure with S=1, so each leaf lands with one
``lax.dynamic_update_slice`` — cheap, donation-friendly, and
jit-traceable with a traced slot index.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

Array = jax.Array


def init_pool_cache(cfg: ModelConfig, n_slots: int, cache_len: int,
                    rt: Optional[T.Runtime] = None) -> dict:
    """A decode cache for S slots with PER-SLOT positions: identical to
    ``init_cache(cfg, batch=S, cache_len)`` except ``len`` is (S,)."""
    c = T.init_cache(cfg, n_slots, cache_len, rt or T.Runtime())
    c["len"] = jnp.zeros((n_slots,), jnp.int32)
    return c


def _batch_axis(path) -> int:
    """Hybrid tail blocks are per-layer dicts keyed under 'tail' with
    leaves (S, ...); every other leaf is layer-stacked (L, S, ...)."""
    for p in path:
        if getattr(p, "key", None) == "tail":
            return 0
    return 1


def scatter_slot(pool_cache: dict, req_cache: dict, slot: Array) -> dict:
    """Write a prefilled single-request cache (batch axis of size 1) into
    slot ``slot`` of the pool.  ``slot`` may be a traced int32 scalar."""
    pool_no = dict(pool_cache)
    req_no = dict(req_cache)
    pool_len = pool_no.pop("len")
    req_len = req_no.pop("len")

    def put(path, pool_leaf, req_leaf):
        ax = _batch_axis(path)
        starts = [jnp.zeros((), jnp.int32)] * pool_leaf.ndim
        starts[ax] = jnp.asarray(slot, jnp.int32)
        return jax.lax.dynamic_update_slice(
            pool_leaf, req_leaf.astype(pool_leaf.dtype), tuple(starts))

    out = jax.tree_util.tree_map_with_path(put, pool_no, req_no)
    out["len"] = pool_len.at[slot].set(
        jnp.asarray(req_len, jnp.int32).reshape(()))
    return out


def gather_slot(pool_cache: dict, slot: Array) -> dict:
    """Slice one slot back out as a single-request cache (test helper /
    debugging; the inverse of ``scatter_slot``)."""
    pool_no = dict(pool_cache)
    pool_len = pool_no.pop("len")

    def take(path, leaf):
        ax = _batch_axis(path)
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)

    out = jax.tree_util.tree_map_with_path(take, pool_no)
    out["len"] = pool_len[slot]
    return out
