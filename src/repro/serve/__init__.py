"""Continuous-batching serving engine.

Slot-stacked cache pool (:mod:`repro.serve.pool`), fused M-step decode
blocks with on-device sampling (:mod:`repro.serve.engine`), and a tiny
host-side FIFO scheduler (:mod:`repro.serve.scheduler`).  The legacy
per-token loop survives as :func:`naive_generate` — the bit-identity
oracle and the benchmark baseline.
"""
from repro.serve.engine import ServeConfig, ServeEngine, naive_generate
from repro.serve.pool import gather_slot, init_pool_cache, scatter_slot
from repro.serve.scheduler import (FifoScheduler, Request, RequestRecord,
                                   poisson_requests)

__all__ = [
    "ServeConfig", "ServeEngine", "naive_generate",
    "init_pool_cache", "scatter_slot", "gather_slot",
    "FifoScheduler", "Request", "RequestRecord", "poisson_requests",
]
