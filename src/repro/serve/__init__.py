"""Continuous-batching serving engine with a resilience layer.

Slot-stacked cache pool (:mod:`repro.serve.pool`), fused M-step decode
blocks with on-device sampling and per-slot fault guards
(:mod:`repro.serve.engine`), a host-side FIFO scheduler with
deadline-based load shedding and a retry lane
(:mod:`repro.serve.scheduler`), and a deterministic chaos-injection
harness (:mod:`repro.serve.faults`).  The legacy per-token loop survives
as :func:`naive_generate` — the bit-identity oracle and the benchmark
baseline.
"""
from repro.serve.engine import ServeConfig, ServeEngine, naive_generate
from repro.serve.faults import FaultPlan, SimulatedCrash, seeded_plan
from repro.serve.pool import gather_slot, init_pool_cache, scatter_slot
from repro.serve.scheduler import (TERMINAL_STATES, FifoScheduler, Request,
                                   RequestRecord, poisson_requests,
                                   state_counts)

__all__ = [
    "ServeConfig", "ServeEngine", "naive_generate",
    "FaultPlan", "SimulatedCrash", "seeded_plan",
    "init_pool_cache", "scatter_slot", "gather_slot",
    "FifoScheduler", "Request", "RequestRecord", "poisson_requests",
    "TERMINAL_STATES", "state_counts",
]
