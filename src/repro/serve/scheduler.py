"""Request stream + admission bookkeeping for the serving engine.

The scheduler is deliberately host-side and tiny: arrival ordering, FIFO
admission into free slots, and per-request accounting (arrival / first
token / finish timestamps).  Everything latency-critical lives in the
compiled engine; the scheduler only runs between decode blocks, so its
cost is amortised over M tokens per slot.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Request:
    """One generation request.  ``arrival_s`` is seconds after stream
    start (0 = already queued); ``max_new`` overrides the engine default
    (total generated tokens, including the prefill-sampled first one);
    ``extras`` carries modality inputs (``image_embeds`` / ``enc_embeds``)
    for VLM / audio families."""
    rid: int
    tokens: Tuple[int, ...]
    arrival_s: float = 0.0
    max_new: Optional[int] = None
    extras: tuple = ()                 # tuple of (name, array) pairs


@dataclass
class RequestRecord:
    """Per-request serving telemetry, filled in by the engine."""
    request: Request
    tokens: List[int] = field(default_factory=list)
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    slot: Optional[int] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.request.arrival_s


def poisson_requests(n: int, rate: float, *, prompt_len: int,
                     vocab_size: int, seed: int = 0,
                     max_new: Optional[int] = None) -> List[Request]:
    """n requests with Poisson arrivals at ``rate`` req/s (rate <= 0 means
    all arrive at t=0) and uniform random prompt tokens."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        if rate > 0:
            t += -math.log(1.0 - rng.random()) / rate
        out.append(Request(
            rid=i,
            tokens=tuple(rng.randrange(vocab_size) for _ in range(prompt_len)),
            arrival_s=t if rate > 0 else 0.0,
            max_new=max_new))
    return out


class FifoScheduler:
    """Arrival-ordered FIFO queue over a fixed slot set."""

    def __init__(self, requests: List[Request], n_slots: int):
        self.pending: List[Request] = sorted(requests,
                                             key=lambda r: r.arrival_s)
        self.records: Dict[int, RequestRecord] = {
            r.rid: RequestRecord(request=r) for r in requests}
        self.free_slots: List[int] = list(range(n_slots))
        self.slot_rid: List[Optional[int]] = [None] * n_slots

    def next_arrival(self) -> Optional[float]:
        return self.pending[0].arrival_s if self.pending else None

    def admissible(self, now_s: float) -> bool:
        return bool(self.pending and self.free_slots
                    and self.pending[0].arrival_s <= now_s)

    def pop(self, now_s: float) -> Tuple[Request, int]:
        """Claim (request, slot) for admission; caller must be
        ``admissible``."""
        req = self.pending.pop(0)
        slot = self.free_slots.pop(0)
        rec = self.records[req.rid]
        rec.admitted_s = now_s
        rec.slot = slot
        self.slot_rid[slot] = req.rid
        return req, slot

    def release(self, slot: int, now_s: float) -> None:
        rid = self.slot_rid[slot]
        if rid is not None:
            self.records[rid].finished_s = now_s
        self.slot_rid[slot] = None
        self.free_slots.append(slot)

    @property
    def done(self) -> bool:
        return not self.pending and all(r is None for r in self.slot_rid)
