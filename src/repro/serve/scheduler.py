"""Request stream + admission bookkeeping for the serving engine.

The scheduler is deliberately host-side and tiny: arrival ordering, FIFO
admission into free slots, and per-request accounting (arrival / first
token / finish timestamps).  Everything latency-critical lives in the
compiled engine; the scheduler only runs between decode blocks, so its
cost is amortised over M tokens per slot.

Resilience layer (PR 8): every request ends in EXACTLY ONE terminal
state — ``completed``, ``shed`` (admission control dropped it before it
ever held a slot), ``timed_out`` (its completion deadline expired while
decoding), or ``failed`` (a device fault or stall exhausted its retry
budget).  Admission control is deadline-based load shedding: a bounded
arrived-queue (``queue_cap``) plus TTFT-deadline rejection — a request
that could no longer receive its first token in time is shed instead of
rotting in the queue, so under overload goodput degrades gracefully and
the TTFT of what IS served stays bounded.  Transient device faults
requeue the request through a retry lane with backoff and a bounded
attempt budget.
"""
from __future__ import annotations

import math
import random
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

#: The four terminal request states.  ``state_counts`` tallies them and
#: the chaos gate in ``benchmarks/check_smoke.py`` asserts they account
#: for every request.
TERMINAL_STATES = ("completed", "shed", "timed_out", "failed")


@dataclass(frozen=True)
class Request:
    """One generation request.  ``arrival_s`` is seconds after stream
    start (0 = already queued); ``max_new`` overrides the engine default
    (total generated tokens, including the prefill-sampled first one);
    ``extras`` carries modality inputs (``image_embeds`` / ``enc_embeds``)
    for VLM / audio families.  ``ttft_deadline_s`` / ``deadline_s`` are
    per-request SLOs RELATIVE to arrival (None = the engine-level
    default): miss the first-token deadline while still queued and the
    request is shed; miss the completion deadline mid-decode and the
    watchdog cancels the slot."""
    rid: int
    tokens: Tuple[int, ...]
    arrival_s: float = 0.0
    max_new: Optional[int] = None
    extras: tuple = ()                 # tuple of (name, array) pairs
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None


@dataclass
class RequestRecord:
    """Per-request serving telemetry, filled in by the engine.

    ``state`` walks queued -> running -> one of ``TERMINAL_STATES``
    (a retried request goes back to queued); ``attempts`` counts
    admissions, ``faults`` counts device-guard trips attributed to this
    request."""
    request: Request
    tokens: List[int] = field(default_factory=list)
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    slot: Optional[int] = None
    state: str = "queued"
    attempts: int = 0
    faults: int = 0

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.request.arrival_s

    @property
    def retries(self) -> int:
        return max(0, self.attempts - 1)


def state_counts(records: Dict[int, "RequestRecord"]) -> Dict[str, int]:
    """Terminal-state tally over a record dict (non-terminal states
    appear under their own name, so an unfinished run is visible)."""
    c = Counter(r.state for r in records.values())
    out = {s: c.pop(s, 0) for s in TERMINAL_STATES}
    out.update(c)
    return out


def poisson_requests(n: int, rate: float, *, prompt_len: int,
                     vocab_size: int, seed: int = 0,
                     max_new: Optional[int] = None) -> List[Request]:
    """n requests with Poisson arrivals at ``rate`` req/s (rate <= 0 means
    all arrive at t=0) and uniform random prompt tokens."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        if rate > 0:
            t += -math.log(1.0 - rng.random()) / rate
        out.append(Request(
            rid=i,
            tokens=tuple(rng.randrange(vocab_size) for _ in range(prompt_len)),
            arrival_s=t if rate > 0 else 0.0,
            max_new=max_new))
    return out


class FifoScheduler:
    """Arrival-ordered FIFO queue over a fixed slot set, with a retry
    lane and deadline-based shedding.

    Admission order: ready retries first (they already waited once),
    then arrivals in order.  The retry lane assumes a single constant
    backoff per run (the engine's ``retry_backoff_s``), so its ready
    times are monotone in append order and the head is always the
    earliest.
    """

    def __init__(self, requests: List[Request], n_slots: int, *,
                 queue_cap: Optional[int] = None,
                 ttft_deadline_s: Optional[float] = None,
                 deadline_s: Optional[float] = None):
        dupes = [rid for rid, n in
                 Counter(r.rid for r in requests).items() if n > 1]
        if dupes:
            raise ValueError(f"duplicate request rids {sorted(dupes)}: "
                             f"records would silently overwrite each other")
        self.pending: Deque[Request] = deque(
            sorted(requests, key=lambda r: r.arrival_s))
        self.retry_q: Deque[Tuple[float, Request]] = deque()
        self.records: Dict[int, RequestRecord] = {
            r.rid: RequestRecord(request=r) for r in requests}
        self.free_slots: Deque[int] = deque(range(n_slots))
        self.slot_rid: List[Optional[int]] = [None] * n_slots
        self.queue_cap = queue_cap
        self.default_ttft_deadline_s = ttft_deadline_s
        self.default_deadline_s = deadline_s

    # ------------------------------------------------------------ SLOs
    def _ttft_deadline(self, req: Request) -> float:
        rel = req.ttft_deadline_s if req.ttft_deadline_s is not None \
            else self.default_ttft_deadline_s
        return math.inf if rel is None else req.arrival_s + rel

    def abs_deadline(self, rid: int) -> float:
        """Absolute completion deadline for an admitted request (inf if
        no deadline applies)."""
        req = self.records[rid].request
        rel = req.deadline_s if req.deadline_s is not None \
            else self.default_deadline_s
        return math.inf if rel is None else req.arrival_s + rel

    def shed_expired(self, now_s: float) -> int:
        """Admission control, run at every block boundary: drop queued
        requests whose TTFT deadline has already passed (they can no
        longer be served in time) and, with a ``queue_cap``, the newest
        arrived requests beyond the cap (bounded queue: reject rather
        than build unbounded latency).  The cap applies to requests that
        will actually WAIT — arrivals a currently-free slot can admit
        this same boundary don't count against it.  Returns the number
        shed."""
        shed = 0
        keep: Deque[Request] = deque()
        arrived: List[Request] = []
        for req in self.pending:
            if now_s > self._ttft_deadline(req):
                self._mark_shed(req, now_s)
                shed += 1
            elif req.arrival_s <= now_s:
                arrived.append(req)
                keep.append(req)
            else:
                keep.append(req)
        cap = (None if self.queue_cap is None
               else self.queue_cap + len(self.free_slots))
        if cap is not None and len(arrived) > cap:
            for req in arrived[cap:]:
                keep.remove(req)
                self._mark_shed(req, now_s)
                shed += 1
        self.pending = keep
        return shed

    def _mark_shed(self, req: Request, now_s: float) -> None:
        rec = self.records[req.rid]
        rec.state = "shed"
        rec.finished_s = now_s

    # ------------------------------------------------------- admission
    def next_ready(self) -> Optional[float]:
        """Earliest instant at which some queued request becomes
        admissible (None when nothing is queued)."""
        times = []
        if self.pending:
            times.append(self.pending[0].arrival_s)
        if self.retry_q:
            times.append(self.retry_q[0][0])
        return min(times) if times else None

    def next_arrival(self) -> Optional[float]:
        return self.pending[0].arrival_s if self.pending else None

    def admissible(self, now_s: float) -> bool:
        if not self.free_slots:
            return False
        if self.retry_q and self.retry_q[0][0] <= now_s:
            return True
        return bool(self.pending and self.pending[0].arrival_s <= now_s)

    def pop(self, now_s: float) -> Tuple[Request, int]:
        """Claim (request, slot) for admission; caller must be
        ``admissible``."""
        if self.retry_q and self.retry_q[0][0] <= now_s:
            _, req = self.retry_q.popleft()
        else:
            req = self.pending.popleft()
        slot = self.free_slots.popleft()
        rec = self.records[req.rid]
        rec.admitted_s = now_s
        rec.slot = slot
        rec.state = "running"
        rec.attempts += 1
        self.slot_rid[slot] = req.rid
        return req, slot

    # ------------------------------------------------------ slot exits
    def release(self, slot: int, now_s: float,
                state: str = "completed") -> None:
        """Return a slot to the free list with its request in terminal
        ``state``.  Releasing an already-free slot raises — double
        release would put the slot in the free list twice and hand one
        physical slot to two requests."""
        rid = self.slot_rid[slot]
        if rid is None:
            raise ValueError(f"release of slot {slot}, which is already "
                             f"free — double release would duplicate it "
                             f"in the free list")
        if state not in TERMINAL_STATES:
            raise ValueError(f"release state {state!r} not one of "
                             f"{TERMINAL_STATES}")
        rec = self.records[rid]
        rec.finished_s = now_s
        rec.state = state
        self.slot_rid[slot] = None
        self.free_slots.append(slot)

    def requeue(self, slot: int, ready_s: float) -> None:
        """Reclaim a faulted/stuck slot and send its request back
        through the retry lane: tokens from the failed attempt are
        discarded (the retry re-prefills from the prompt) and the
        request becomes admissible again at ``ready_s``."""
        rid = self.slot_rid[slot]
        if rid is None:
            raise ValueError(f"requeue of slot {slot}, which is already "
                             f"free")
        rec = self.records[rid]
        rec.tokens = []
        rec.first_token_s = None
        rec.slot = None
        rec.state = "queued"
        self.slot_rid[slot] = None
        self.free_slots.append(slot)
        self.retry_q.append((ready_s, rec.request))

    @property
    def done(self) -> bool:
        return (not self.pending and not self.retry_q
                and all(r is None for r in self.slot_rid))

    # ------------------------------------------------ snapshot support
    def to_meta(self) -> dict:
        """JSON-serialisable scheduler state for the serve snapshot.
        Modality ``extras`` are device arrays and cannot ride the JSON
        header, so snapshotting is refused while a request that might
        still need re-prefill (queued, retrying, or running) carries
        extras."""
        for req in ([r for r in self.pending]
                    + [r for _, r in self.retry_q]
                    + [self.records[rid].request
                       for rid in self.slot_rid if rid is not None]):
            if req.extras:
                raise ValueError(
                    f"request {req.rid} carries modality extras and is "
                    f"not terminal: serve snapshots cannot serialise "
                    f"extras arrays")

        def req_meta(r: Request) -> dict:
            return {"rid": r.rid, "tokens": list(r.tokens),
                    "arrival_s": r.arrival_s, "max_new": r.max_new,
                    "ttft_deadline_s": r.ttft_deadline_s,
                    "deadline_s": r.deadline_s}

        return {
            "requests": [req_meta(rec.request)
                         for rec in self.records.values()],
            "records": {str(rid): {
                "tokens": [int(t) for t in rec.tokens],
                "admitted_s": rec.admitted_s,
                "first_token_s": rec.first_token_s,
                "finished_s": rec.finished_s,
                "slot": rec.slot, "state": rec.state,
                "attempts": rec.attempts, "faults": rec.faults,
            } for rid, rec in self.records.items()},
            "pending": [r.rid for r in self.pending],
            "retry_q": [[ready, r.rid] for ready, r in self.retry_q],
            "free_slots": list(self.free_slots),
            "slot_rid": list(self.slot_rid),
            "queue_cap": self.queue_cap,
            "ttft_deadline_s": self.default_ttft_deadline_s,
            "deadline_s": self.default_deadline_s,
        }

    @classmethod
    def from_meta(cls, meta: dict) -> "FifoScheduler":
        reqs = {m["rid"]: Request(rid=m["rid"], tokens=tuple(m["tokens"]),
                                  arrival_s=m["arrival_s"],
                                  max_new=m["max_new"],
                                  ttft_deadline_s=m["ttft_deadline_s"],
                                  deadline_s=m["deadline_s"])
                for m in meta["requests"]}
        sched = cls(list(reqs.values()), len(meta["slot_rid"]),
                    queue_cap=meta["queue_cap"],
                    ttft_deadline_s=meta["ttft_deadline_s"],
                    deadline_s=meta["deadline_s"])
        for rid_s, rm in meta["records"].items():
            rec = sched.records[int(rid_s)]
            rec.tokens = list(rm["tokens"])
            rec.admitted_s = rm["admitted_s"]
            rec.first_token_s = rm["first_token_s"]
            rec.finished_s = rm["finished_s"]
            rec.slot = rm["slot"]
            rec.state = rm["state"]
            rec.attempts = rm["attempts"]
            rec.faults = rm["faults"]
        sched.pending = deque(reqs[rid] for rid in meta["pending"])
        sched.retry_q = deque((ready, reqs[rid])
                              for ready, rid in meta["retry_q"])
        sched.free_slots = deque(meta["free_slots"])
        sched.slot_rid = [rid if rid is None else int(rid)
                          for rid in meta["slot_rid"]]
        return sched
