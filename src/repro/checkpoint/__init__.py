from repro.checkpoint.checkpoint import (load_checkpoint, read_meta,
                                         save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "read_meta"]
