from repro.checkpoint.checkpoint import (CheckpointError, load_checkpoint,
                                         read_meta, save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "read_meta",
           "CheckpointError"]
