"""Pytree checkpointing (numpy .npz + json treedef; no orbax in env).

Handles arbitrary nested dict/list/tuple pytrees with array or scalar
leaves, bf16 included (stored via uint16 view).  Atomic write (tmp +
rename) so a crashed save never corrupts the previous checkpoint.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


class CheckpointError(RuntimeError):
    """A checkpoint file is unreadable — truncated, bit-flipped, or not a
    checkpoint at all.  Message always carries the path and, where known,
    expected-vs-found sizes, so an operator can tell a half-written file
    from a wrong path at a glance."""


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = {}
    metas = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            out[f"leaf_{i}"] = arr.view(np.uint16)
            metas.append(_BF16)
        else:
            out[f"leaf_{i}"] = arr
            metas.append(str(arr.dtype))
    return out, (treedef, metas)


def save_checkpoint(path: str, tree, step: int = 0,
                    meta: Dict[str, Any] = None) -> None:
    """``meta`` is an optional JSON-serialisable dict stored alongside the
    tree (e.g. the federation records its server-opt config so a restore
    into a mismatched block-carry structure fails loudly); read it back
    with ``read_meta``."""
    arrays, (treedef, metas) = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    header = {"treedef": str(treedef), "dtypes": metas, "step": step,
              "n_leaves": len(metas), "user_meta": meta or {}}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __meta__=json.dumps(header), **arrays)
        src = tmp if tmp.endswith(".npz") else tmp + ".npz"
        if not os.path.exists(src):      # np.savez appends .npz
            src = tmp
        os.replace(src, path)
    finally:
        for f in (tmp, tmp + ".npz"):
            if os.path.exists(f):
                os.remove(f)


def _open_checkpoint(path: str):
    """np.load with the opaque failure modes translated into
    ``CheckpointError``: a truncated download / half-copied file raises
    zipfile or struct errors deep inside numpy; a bit-flipped member
    raises on CRC or on json decode.  All of them become one clear error
    carrying the path and the on-disk vs expected sizes."""
    try:
        found = os.path.getsize(path)
    except OSError as e:
        raise CheckpointError(f"checkpoint {path!r}: {e}") from e
    try:
        data = np.load(path, allow_pickle=False)
    except Exception as e:
        raise CheckpointError(
            f"checkpoint {path!r} is not a readable .npz archive "
            f"({found} bytes on disk): {type(e).__name__}: {e} — the "
            f"file is truncated, corrupt, or not a checkpoint") from e
    return data, found


def _read_header(data, path: str, found: int) -> Dict[str, Any]:
    try:
        if "__meta__" not in data:
            raise KeyError("__meta__")
        return json.loads(str(data["__meta__"]))
    except Exception as e:
        data.close()
        raise CheckpointError(
            f"checkpoint {path!r} ({found} bytes on disk) has no readable "
            f"__meta__ header: {type(e).__name__}: {e} — the archive is "
            f"corrupt or was not written by save_checkpoint") from e


def read_meta(path: str) -> Dict[str, Any]:
    """User metadata stored by ``save_checkpoint(..., meta=...)`` (empty
    dict for checkpoints written before meta support existed).  Raises
    ``CheckpointError`` on a truncated/corrupt file."""
    data, found = _open_checkpoint(path)
    with data:
        return _read_header(data, path, found).get("user_meta", {})


def load_checkpoint(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype-checked).
    Structure mismatches raise ``ValueError`` (wrong checkpoint for this
    state); unreadable files — truncated, bit-flipped, not an archive —
    raise ``CheckpointError`` with the path and expected-vs-found sizes."""
    data, found = _open_checkpoint(path)
    with data:
        meta = _read_header(data, path, found)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        n_expected = meta["n_leaves"]
        if len(leaves_like) != n_expected:
            raise ValueError(
                f"checkpoint has {n_expected} leaves, target structure "
                f"has {len(leaves_like)}")
        stored = [k for k in data.files if k.startswith("leaf_")]
        if len(stored) != n_expected:
            raise CheckpointError(
                f"checkpoint {path!r} ({found} bytes on disk) is "
                f"truncated: header promises {n_expected} leaves, archive "
                f"holds {len(stored)}")
        out = []
        for i, (ref_leaf, dt) in enumerate(zip(leaves_like, meta["dtypes"])):
            try:
                arr = data[f"leaf_{i}"]
            except Exception as e:
                raise CheckpointError(
                    f"checkpoint {path!r}: leaf_{i} of {n_expected} is "
                    f"unreadable ({found} bytes on disk): "
                    f"{type(e).__name__}: {e} — truncated or bit-flipped "
                    f"archive member") from e
            if dt == _BF16:
                arr = arr.view(jnp.bfloat16)
            leaf = jnp.asarray(arr)
            if hasattr(ref_leaf, "shape") and leaf.shape != ref_leaf.shape:
                expected = int(np.prod(ref_leaf.shape)) \
                    if hasattr(ref_leaf, "shape") else -1
                raise CheckpointError(
                    f"checkpoint {path!r}: leaf {i} has shape "
                    f"{leaf.shape} ({leaf.size} elements), expected "
                    f"{ref_leaf.shape} ({expected} elements) — truncated "
                    f"write or a checkpoint from a different state "
                    f"structure")
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), meta["step"]
