"""Pytree checkpointing (numpy .npz + json treedef; no orbax in env).

Handles arbitrary nested dict/list/tuple pytrees with array or scalar
leaves, bf16 included (stored via uint16 view).  Atomic write (tmp +
rename) so a crashed save never corrupts the previous checkpoint.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = {}
    metas = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            out[f"leaf_{i}"] = arr.view(np.uint16)
            metas.append(_BF16)
        else:
            out[f"leaf_{i}"] = arr
            metas.append(str(arr.dtype))
    return out, (treedef, metas)


def save_checkpoint(path: str, tree, step: int = 0,
                    meta: Dict[str, Any] = None) -> None:
    """``meta`` is an optional JSON-serialisable dict stored alongside the
    tree (e.g. the federation records its server-opt config so a restore
    into a mismatched block-carry structure fails loudly); read it back
    with ``read_meta``."""
    arrays, (treedef, metas) = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    header = {"treedef": str(treedef), "dtypes": metas, "step": step,
              "n_leaves": len(metas), "user_meta": meta or {}}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __meta__=json.dumps(header), **arrays)
        src = tmp if tmp.endswith(".npz") else tmp + ".npz"
        if not os.path.exists(src):      # np.savez appends .npz
            src = tmp
        os.replace(src, path)
    finally:
        for f in (tmp, tmp + ".npz"):
            if os.path.exists(f):
                os.remove(f)


def read_meta(path: str) -> Dict[str, Any]:
    """User metadata stored by ``save_checkpoint(..., meta=...)`` (empty
    dict for checkpoints written before meta support existed)."""
    with np.load(path, allow_pickle=False) as data:
        return json.loads(str(data["__meta__"])).get("user_meta", {})


def load_checkpoint(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        if len(leaves_like) != meta["n_leaves"]:
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, target structure "
                f"has {len(leaves_like)}")
        out = []
        for i, (ref_leaf, dt) in enumerate(zip(leaves_like, meta["dtypes"])):
            arr = data[f"leaf_{i}"]
            if dt == _BF16:
                arr = arr.view(jnp.bfloat16)
            leaf = jnp.asarray(arr)
            if hasattr(ref_leaf, "shape") and leaf.shape != ref_leaf.shape:
                raise ValueError(f"leaf {i}: shape {leaf.shape} != "
                                 f"{ref_leaf.shape}")
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), meta["step"]
