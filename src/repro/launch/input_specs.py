"""Abstract inputs (ShapeDtypeStruct — no allocation) for every
(architecture x input-shape) combination, plus their PartitionSpecs.

``train_4k`` lowers the federated round step (tokens+labels+anchors+G_bar);
``prefill_32k`` lowers prefill; ``decode_32k`` / ``long_500k`` lower a
single-token decode against an S-length cache.  Modality frontends are
stubs per the brief: VLM batches carry CLIP-width patch embeddings, audio
batches carry 1500 whisper-frame embeddings.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, InputShape, ModelConfig
from repro.launch import mesh as mesh_mod
from repro.launch import shardings as shd
from repro.models import transformer as T

ANCHORS = 32            # public anchor set size B (Gram is 32x32)
ANCHOR_LEN = 128        # anchor token length

f = jax.ShapeDtypeStruct


def runtime_for(cfg: ModelConfig, shape: InputShape, mesh) -> T.Runtime:
    window = 0
    if shape.name == "long_500k" and cfg.family in ("dense", "vlm") \
            and not cfg.sliding_window:
        window = 8192            # flagged SWA variant (DESIGN.md)
    return T.Runtime(
        mesh=mesh,
        ep_axis="model" if cfg.moe is not None else None,
        batch_axes=mesh_mod.batch_axes(mesh) if mesh is not None else (),
        remat=(shape.kind == "train"),
        window_override=window,
        # sequence-parallel residual stream: required to fit remat residuals
        # in HBM for the big archs at 4k x 256 (see DESIGN.md / §Perf)
        seq_shard=(shape.kind in ("train", "prefill")),
    )


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.name in cfg.skip_shapes:
        return cfg.long_context_variant or "skipped per config"
    return None


def train_batch_specs(cfg: ModelConfig, shape: InputShape, mesh,
                      data_axes=None):
    b, s = shape.global_batch, shape.seq_len
    k = mesh_mod.n_nodes(mesh)
    dt = _dtype(cfg)
    batch = {}
    if cfg.family == "vlm":
        n_img = cfg.n_image_tokens
        batch["tokens"] = f((b, s - n_img), jnp.int32)
        batch["labels"] = f((b, s - n_img), jnp.int32)
        batch["image_embeds"] = f((b, n_img, cfg.image_embed_dim), dt)
    elif cfg.family == "audio":
        batch["tokens"] = f((b, s), jnp.int32)
        batch["labels"] = f((b, s), jnp.int32)
        batch["enc_embeds"] = f((b, cfg.encoder_seq_len,
                                 cfg.encoder_embed_dim), dt)
        batch["anchor_enc_embeds"] = f(
            (k, ANCHORS, cfg.encoder_seq_len, cfg.encoder_embed_dim), dt)
    else:
        batch["tokens"] = f((b, s), jnp.int32)
        batch["labels"] = f((b, s), jnp.int32)
    batch["anchors"] = f((k, ANCHORS, ANCHOR_LEN), jnp.int32)
    specs = shd.batch_specs(batch, mesh, data_axes)
    # anchors: leading dim = node count, sharded over the node axes
    node_axes = mesh_mod.batch_axes(mesh)
    a_spec = P(node_axes, None, None)
    specs["anchors"] = a_spec
    if "anchor_enc_embeds" in batch:
        specs["anchor_enc_embeds"] = P(node_axes, None, None, None)
    gbar = f((ANCHORS, ANCHORS), jnp.float32)
    return batch, specs, gbar


def serve_batch_specs(cfg: ModelConfig, shape: InputShape, mesh):
    b, s = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)
    if shape.kind == "prefill":
        batch = {}
        if cfg.family == "vlm":
            batch["tokens"] = f((b, s - cfg.n_image_tokens), jnp.int32)
            batch["image_embeds"] = f((b, cfg.n_image_tokens,
                                       cfg.image_embed_dim), dt)
        elif cfg.family == "audio":
            batch["tokens"] = f((b, s), jnp.int32)
            batch["enc_embeds"] = f((b, cfg.encoder_seq_len,
                                     cfg.encoder_embed_dim), dt)
        else:
            batch["tokens"] = f((b, s), jnp.int32)
        return batch, shd.batch_specs(batch, mesh)
    batch = {"tokens": f((b, 1), jnp.int32)}
    return batch, shd.batch_specs(batch, mesh)


def abstract_cache(cfg: ModelConfig, shape: InputShape, rt: T.Runtime):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len, rt))


def abstract_params(cfg: ModelConfig, lora_spec=None):
    from repro.core import lora as lora_mod

    def build():
        p = T.init_params(jax.random.PRNGKey(0), cfg)
        if lora_spec is not None:
            p = lora_mod.attach_lora(jax.random.PRNGKey(1), p, lora_spec)
        return p
    return jax.eval_shape(build)
