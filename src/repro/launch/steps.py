"""Mesh-scale step functions lowered by the dry-run and the drivers.

``make_fed_train_step`` is the paper's federated round as one SPMD program
(FedSGD form: one local step + precision-weighted aggregation — the
multi-local-step divergent form runs on the node-stacked round engine,
``repro.core.engine.RoundEngine``, via ``launch/train.py``):

  - the mesh batch axes ("pod","data") carry the K federated nodes
    (one node per slice, node k's samples are batch rows k*b_loc:(k+1)*b_loc);
  - each node's anchor pass produces its Gram G_k; loss_k = CE_k +
    lambda*(1-CKA(G_k, G_bar))  (Eq. 3);
  - LAP uncertainties (Eq. 6) give precision weights p_k; total loss
    sum_k p_k * loss_k makes the aggregated update exactly the paper's
    precision-weighted average of per-node GeoLoRA updates (Eq. 4/5 with
    one local step);
  - only side-cars (lora_B / dora_m) receive gradients; the collective
    footprint over the node axes is therefore low-rank-sized — the paper's
    communication claim, visible in the §Roofline collective term.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cka as cka_mod
from repro.core import lora as lora_mod
from repro.core import uncertainty as unc
from repro.models import transformer as T
from repro.models.common import cross_entropy_loss, linear
from repro.optim.adamw import AdamW

Array = jax.Array


def _per_node_ce(logits: Array, labels: Array, k_nodes: int) -> Array:
    """(B, S, V), (B, S) -> (K,) per-node mean CE."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold                                     # (B, S)
    b = nll.shape[0]
    return nll.reshape(k_nodes, b // k_nodes, -1).mean(axis=(1, 2))


def make_fed_train_step(cfg: ModelConfig, rt: T.Runtime, opt: AdamW, *,
                        k_nodes: int, lambda_geo: float = 1.0,
                        aux_coeff: float = 0.01) -> Callable:
    def step(trainable, frozen, opt_state, batch, gbar):
        def loss_fn(train):
            params = lora_mod.combine(train, frozen)
            logits, aux = T.forward(params, batch, cfg, rt)
            task_k = _per_node_ce(logits, batch["labels"], k_nodes)

            # public-anchor pass (per node) -> Grams -> CKA alignment
            anch = batch["anchors"]                       # (K, Ba, La)
            k, ba, la = anch.shape
            anchor_batch = {"tokens": anch.reshape(k * ba, la)}
            if "anchor_enc_embeds" in batch:              # audio anchors
                anchor_batch["enc_embeds"] = \
                    batch["anchor_enc_embeds"].reshape(
                        (k * ba,) + batch["anchor_enc_embeds"].shape[2:])
            _, a_aux = T.forward(params, anchor_batch, cfg, rt)
            pooled_a = a_aux["pooled"].reshape(k, ba, -1)  # (K, Ba, D)
            grams = jax.vmap(cka_mod.cosine_gram)(pooled_a)
            geo_k = jax.vmap(
                lambda g: 1.0 - cka_mod.cka(g, gbar))(grams)

            # LAP precision weights (Eq. 6) — stop-grad, server-side math
            pooled_b = aux["pooled"].reshape(k, -1, aux["pooled"].shape[-1])
            u = jax.vmap(unc.lap_uncertainty)(
                jax.lax.stop_gradient(pooled_b),
                jax.lax.stop_gradient(pooled_a))          # (K, b_loc)
            p = jax.vmap(unc.node_precision)(u)
            w = jax.lax.stop_gradient(unc.precision_weights(p))

            loss = (w * (task_k + lambda_geo * geo_k)).sum()
            loss = loss + aux_coeff * (aux["load_balance"] + aux["router_z"])
            metrics = {"task": task_k.mean(), "geo": geo_k.mean(),
                       "weights": w, "gbar_new": grams.mean(0)}
            return loss, metrics

        grads, metrics = jax.grad(loss_fn, has_aux=True)(trainable)
        new_train, new_opt = opt.update(grads, opt_state, trainable)
        return new_train, new_opt, metrics["gbar_new"], \
            {"task": metrics["task"], "geo": metrics["geo"]}

    return step


def make_lm_train_step(cfg: ModelConfig, rt: T.Runtime, opt: AdamW,
                       trainable_only: bool = False) -> Callable:
    """Plain LM training step (FedAvg-full baseline / centralised)."""
    def step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = T.forward(p, batch, cfg, rt)
            loss = cross_entropy_loss(logits, batch["labels"])
            return loss + 0.01 * (aux["load_balance"] + aux["router_z"]), loss
        grads, ce = jax.grad(loss_fn, has_aux=True)(params)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, ce
    return step


def make_prefill_step(cfg: ModelConfig, rt: T.Runtime) -> Callable:
    def step(params, batch):
        return T.prefill(params, batch, cfg, rt,
                         cache_len=_prefill_cache_len(batch, cfg))
    return step


def _prefill_cache_len(batch, cfg) -> int:
    s = batch["tokens"].shape[1]
    if cfg.family == "vlm" and "image_embeds" in batch:
        s += batch["image_embeds"].shape[1]
    return s + 128          # decode headroom


def make_decode_step(cfg: ModelConfig, rt: T.Runtime) -> Callable:
    def step(params, cache, batch):
        return T.decode_step(params, cache, batch, cfg, rt)
    return step
