"""Mesh-scale step functions lowered by the dry-run and the drivers.

``make_fed_train_step`` is the paper's federated round as one SPMD program
— the FedSGD form, now FOLDED into the node-stacked round engine
(``repro.core.engine.RoundEngine`` with E=1 and the round's batches passed
in), so the one-local-step form and the multi-step divergent form
(``launch/train.py``) share the engine's server math (consensus Gram, LAP
precision weights, precision-weighted side-car averaging) instead of
duplicating it:

  - the mesh batch axes ("pod","data") carry the K federated nodes
    (node k's samples are batch rows k*b_loc:(k+1)*b_loc, reshaped onto
    the engine's node axis);
  - each node runs ONE local step on loss_k = CE_k +
    lambda*(1-CKA(G_k, G_bar))  (Eq. 3), producing its own AdamW update;
  - the engine's server step averages the per-node updates with LAP
    precision weights (Eq. 6) and averages the consensus Gram — exactly
    the paper's precision-weighted average of per-node GeoLoRA updates
    (Eq. 4/5 with one local step).  The server keeps one optimizer state:
    the per-node AdamW moments are precision-weight-averaged the same way;
  - only side-cars (lora_B / dora_m) receive gradients; the collective
    footprint over the node axes is therefore low-rank-sized — the paper's
    communication claim, visible in the §Roofline collective term.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cka as cka_mod
from repro.core import lora as lora_mod
from repro.core.engine import EngineConfig, RoundEngine
from repro.models import transformer as T
from repro.models.common import cross_entropy_loss
from repro.optim.adamw import AdamW

Array = jax.Array


def _none_map(f, *trees):
    return jax.tree.map(lambda *xs: None if xs[0] is None else f(*xs),
                        *trees, is_leaf=lambda x: x is None)


def make_fed_train_step(cfg: ModelConfig, rt: T.Runtime, opt: AdamW, *,
                        k_nodes: int, lambda_geo: float = 1.0,
                        aux_coeff: float = 0.01) -> Callable:
    ecfg = EngineConfig(n_nodes=k_nodes, local_steps=1,
                        aggregation="precision")

    def step(trainable, frozen, opt_state, batch, gbar):
        def local_step(train_k, opt_k, key_k, gb, _statics, bk):
            def loss_fn(train):
                params = lora_mod.combine(train, frozen)
                model_batch = {n: v for n, v in bk.items()
                               if not n.startswith("anchor")}
                logits, aux = T.forward(params, model_batch, cfg, rt)
                task = cross_entropy_loss(logits, bk["labels"])

                # public-anchor pass -> Gram -> CKA alignment (loss-side
                # gram stays the differentiable jnp reference; the server
                # side gram goes through the engine's backend dispatch)
                anchor_batch = {"tokens": bk["anchors"]}
                if "anchor_enc_embeds" in bk:              # audio anchors
                    anchor_batch["enc_embeds"] = bk["anchor_enc_embeds"]
                _, a_aux = T.forward(params, anchor_batch, cfg, rt)
                gram = cka_mod.cosine_gram(a_aux["pooled"])
                geo = 1.0 - cka_mod.cka(gram, gb)
                loss = task + lambda_geo * geo \
                    + aux_coeff * (aux["load_balance"] + aux["router_z"])
                return loss, (task, geo, aux["pooled"], a_aux["pooled"])

            grads, (task, geo, pooled, pooled_a) = \
                jax.grad(loss_fn, has_aux=True)(train_k)
            new_train, new_opt = opt.update(grads, opt_k, train_k)
            return new_train, new_opt, key_k, {
                "task": task, "geo": geo,
                "pooled": pooled, "pooled_a": pooled_a}

        # LM nodes ship every trainable leaf; one width bucket.  The engine
        # is built per TRACE (construction is trace-time-cheap): the local
        # step closes over `frozen` and the shipped mask mirrors
        # `trainable`, both of which are arguments of this jitted step.
        # jit=False inlines the round into the caller's compilation
        # boundary (dryrun/tests own jit, shardings and donation).
        shipped = jax.tree.map(lambda p: None if p is None else True,
                               trainable, is_leaf=lambda x: x is None)
        engine = RoundEngine(ecfg, opt, local_step, (shipped,), jit=False)

        def bcast(x):
            return jnp.broadcast_to(x, (k_nodes,) + x.shape)

        def node_split(name, x):
            if name.startswith("anchor"):
                return x                                  # already (K, ...)
            return x.reshape((k_nodes, x.shape[0] // k_nodes) + x.shape[1:])

        node_batch = {n: node_split(n, v) for n, v in batch.items()}
        batches = jax.tree.map(lambda x: x[None], node_batch)     # E=1
        node_train = _none_map(bcast, trainable)
        node_opt = {"m": _none_map(bcast, opt_state["m"]),
                    "v": _none_map(bcast, opt_state["v"]),
                    "step": bcast(opt_state["step"])}
        if "round" in opt_state:      # global-round LR schedule counter
            node_opt["round"] = bcast(opt_state["round"])
        keys = jnp.zeros((k_nodes, 2), jnp.uint32)        # data comes in

        trains, opts, _, new_gbar, _, metrics = engine.round_fn(
            (node_train,), (node_opt,), (keys,), gbar, None, (None,),
            (batches,))

        # every leaf is shipped, so each node row holds the precision-
        # weighted average — the server state is row 0
        new_train = _none_map(lambda x: x[0], trains[0])
        w = metrics["weights"].astype(jnp.float32)
        wavg = lambda x: jnp.tensordot(w, x, axes=1).astype(x.dtype)
        new_opt = {"m": _none_map(wavg, opts[0]["m"]),
                   "v": _none_map(wavg, opts[0]["v"]),
                   "step": opts[0]["step"][0]}
        if "round" in opts[0]:
            new_opt["round"] = opts[0]["round"][0]
        return new_train, new_opt, new_gbar, \
            {"task": metrics["scalars"]["task"].mean(),
             "geo": metrics["scalars"]["geo"].mean()}

    return step


def make_lm_train_step(cfg: ModelConfig, rt: T.Runtime, opt: AdamW,
                       trainable_only: bool = False) -> Callable:
    """Plain LM training step (FedAvg-full baseline / centralised)."""
    def step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = T.forward(p, batch, cfg, rt)
            loss = cross_entropy_loss(logits, batch["labels"])
            return loss + 0.01 * (aux["load_balance"] + aux["router_z"]), loss
        grads, ce = jax.grad(loss_fn, has_aux=True)(params)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, ce
    return step


def make_prefill_step(cfg: ModelConfig, rt: T.Runtime) -> Callable:
    def step(params, batch):
        return T.prefill(params, batch, cfg, rt,
                         cache_len=_prefill_cache_len(batch, cfg))
    return step


def _prefill_cache_len(batch, cfg) -> int:
    s = batch["tokens"].shape[1]
    if cfg.family == "vlm" and "image_embeds" in batch:
        s += batch["image_embeds"].shape[1]
    return s + 128          # decode headroom


def make_decode_step(cfg: ModelConfig, rt: T.Runtime) -> Callable:
    def step(params, cache, batch):
        return T.decode_step(params, cache, batch, cfg, rt)
    return step
