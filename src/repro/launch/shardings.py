"""Sharding rule engine: param/batch/cache pytrees -> PartitionSpec trees.

Rules are name-based (the param tree layout is uniform across the zoo):
  - column-parallel linears (wq/wk/wv/gate/up/in_proj/...) shard the flat
    output dim over ``model`` — note this shards H*dh, so it works even when
    the head COUNT is not divisible (llama4's 40 heads, smollm's 9: the flat
    5120/576 dims divide; XLA handles the head reshape);
  - row-parallel linears (wo/down/out_proj/out) shard the input dim;
  - MoE expert stacks shard the expert dim over ``model`` (expert parallel)
    and the per-expert FFN dim over ``data`` (FSDP-style; unsharded on entry
    to the expert shard_map);
  - embeddings / lm_head shard the vocab dim over ``model``;
  - batch-like arrays shard dim0 over ("pod","data") = the federated nodes;
  - every rule falls back to replication when the dim is not divisible
    (logged by ``explain()``); LoRA side-cars are tiny and stay replicated.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

COL_PARALLEL = {"wq", "wk", "wv", "wq_a", "wq_b", "w_dkv", "w_ukv", "gate",
                "up", "in_proj", "in_gate", "in_rec", "w_a", "w_x",
                "lm_head", "x_proj", "dt_proj"}
ROW_PARALLEL = {"wo", "down", "out_proj", "out"}
REPLICATED_LEAVES = {"lora_A", "lora_B", "dora_m", "scale", "conv_w",
                     "conv_b", "dt_bias", "a_log", "d_skip", "lam", "b"}

_FALLBACKS: List[str] = []          # replication decisions, for explain()


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _div(n: int, mesh: Mesh, axis) -> bool:
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= _axis(mesh, a)
    else:
        size = _axis(mesh, axis)
    return size > 1 and n % size == 0


def _spec(ndim: int, dim: int, axis) -> P:
    parts: list = [None] * ndim
    parts[dim] = axis
    return P(*parts)


def _leaf_spec(path: Tuple[str, ...], leaf, mesh: Mesh,
               layout: str = "tp") -> P:
    names = [p for p in path]
    leaf_name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    nd = leaf.ndim
    shape = leaf.shape

    def fallback(why: str) -> P:
        _FALLBACKS.append(f"{'/'.join(names)}: {why} -> replicate")
        return P()

    if nd == 0 or leaf_name in REPLICATED_LEAVES:
        return P()

    if layout == "dp":
        # pure data parallelism: params replicated, batch over every axis —
        # the right mapping for sub-1B models on a 256-chip pod (§Perf).
        return P()
    if layout == "fsdp" and leaf_name in ("embed", "w") \
            and "experts" not in names:
        # ZeRO-3-style (MaxText convention): shard the CONTRACTION/embed dim
        # (dim -2 of a linear; vocab dim of the embedding) so XLA lowers use
        # sites to a weight all-gather instead of resharding activations.
        # Ideal for the paper's GeoLoRA training: base weights are FROZEN
        # (no grad sync) and the gathers overlap with compute (§Perf iter 4+).
        dim = 0 if leaf_name == "embed" else nd - 2
        for axis in (("data", "model"), ("model",), ("data",)):
            if _div(shape[dim], mesh, axis if len(axis) > 1 else axis[0]):
                return _spec(nd, dim, axis if len(axis) > 1 else axis[0])
        # fall back to the widest dim
        wide = max(range(nd), key=lambda i: shape[i])
        for axis in (("data", "model"), ("model",), ("data",)):
            if _div(shape[wide], mesh, axis if len(axis) > 1 else axis[0]):
                return _spec(nd, wide, axis if len(axis) > 1 else axis[0])
        return fallback(f"fsdp {shape[dim]} % mesh")

    if leaf_name == "embed":
        return (_spec(nd, 0, "model") if _div(shape[0], mesh, "model")
                else fallback(f"vocab {shape[0]} % model"))
    if leaf_name == "w":
        inside_experts = "experts" in names
        if inside_experts:
            # (L, E, d, f) / (L, E, f, d): expert dim over model, widest
            # remaining dim over data (FSDP)
            e_dim = nd - 3
            spec: list = [None] * nd
            if _div(shape[e_dim], mesh, "model"):
                spec[e_dim] = "model"
            else:
                _FALLBACKS.append(f"{'/'.join(names)}: experts {shape[e_dim]}"
                                  " % model -> replicate expert dim")
            wide = nd - 1 if shape[nd - 1] >= shape[nd - 2] else nd - 2
            if _div(shape[wide], mesh, "data"):
                spec[wide] = "data"
            return P(*spec)
        if parent in COL_PARALLEL or leaf_name in COL_PARALLEL:
            return (_spec(nd, nd - 1, "model")
                    if _div(shape[-1], mesh, "model")
                    else fallback(f"col {shape[-1]} % model"))
        if parent in ROW_PARALLEL:
            return (_spec(nd, nd - 2, "model")
                    if _div(shape[-2], mesh, "model")
                    else fallback(f"row {shape[-2]} % model"))
        if parent == "router":
            return P()
        if parent in ("adapter", "enc_adapter", "cls_head"):
            return (_spec(nd, nd - 1, "model")
                    if _div(shape[-1], mesh, "model") else P())
        return fallback(f"unmatched linear '{parent}'")
    return P()


def _walk(tree, path, fn):
    if isinstance(tree, dict):
        return {k: _walk(v, path + (k,), fn) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_walk(v, path + (str(i),), fn)
                          for i, v in enumerate(tree))
    if tree is None:
        return None
    return fn(path, tree)


def param_specs(params, mesh: Mesh, layout: str = "tp"):
    return _walk(params, (), lambda p, l: _leaf_spec(p, l, mesh, layout))


def param_shardings(params, mesh: Mesh, layout: str = "tp"):
    return _walk(params, (),
                 lambda p, l: NamedSharding(mesh,
                                            _leaf_spec(p, l, mesh, layout)))


# ----------------------------------------------------------------------
def batch_dim_spec(mesh: Mesh, n: int, data_axes=None) -> Optional[tuple]:
    """Sharding for a batch-like dim of size n over ("pod","data") (or the
    given axes, e.g. all axes for the dp layout)."""
    axes = tuple(a for a in (data_axes or ("pod", "data"))
                 if a in mesh.shape)
    if axes and _div(n, mesh, axes):
        return axes
    # try data only (pod replicated)
    if "data" in mesh.shape and _div(n, mesh, "data"):
        return ("data",)
    return None


def batch_specs(batch, mesh: Mesh, data_axes=None):
    def f(path, leaf):
        if leaf.ndim == 0:
            return P()
        return P(batch_dim_spec(mesh, leaf.shape[0], data_axes),
                 *([None] * (leaf.ndim - 1)))
    return _walk(batch, (), f)


def cache_specs(cache, mesh: Mesh):
    """Decode caches: leaves are (L, B, ...) stacked or (B, ...) tail
    entries; shard the batch dim over nodes, KV-ish inner dims over model
    where divisible."""
    def f(path, leaf):
        name = path[-1] if path else ""
        nd = leaf.ndim
        if nd == 0 or name == "len":
            return P()
        stacked = path[0] != "tail" if path else True
        bdim = 1 if stacked else 0
        if nd <= bdim:
            return P()
        spec: list = [None] * nd
        spec[bdim] = batch_dim_spec(mesh, leaf.shape[bdim])
        if name in ("k", "v", "cross_k", "cross_v") and nd == bdim + 4:
            # NOTE: S-dim sharding (the MLA decode win) was measured 7-15x
            # WORSE for GQA caches — the blockwise KV reshape forces
            # per-block gathers of the sequence-sharded cache (see Perf).
            if _div(leaf.shape[bdim + 2], mesh, "model"):
                spec[bdim + 2] = "model"
        if name in ("c_kv", "k_rope") and nd == bdim + 3:
            # MLA compressed cache: shard the SEQUENCE dim over model —
            # decode attention parallelises over cache positions (softmax
            # partials psum tiny (B,H) stats), and the 576 B/token cache
            # splits 16x per device (§Perf deepseek decode iteration).
            if _div(leaf.shape[bdim + 1], mesh, "model"):
                spec[bdim + 1] = "model"
        if name == "h" and nd == bdim + 3:       # mamba (B, di, N)
            if _div(leaf.shape[bdim + 1], mesh, "model"):
                spec[bdim + 1] = "model"
        if name in ("conv",) and nd == bdim + 3:
            if _div(leaf.shape[bdim + 2], mesh, "model"):
                spec[bdim + 2] = "model"
        if name == "h" and nd == bdim + 2:       # rg-lru (B, w)
            if _div(leaf.shape[bdim + 1], mesh, "model"):
                spec[bdim + 1] = "model"
        return P(*spec)
    return _walk(cache, (), f)


def to_shardings(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def explain() -> List[str]:
    """Replication fallbacks recorded since the last reset."""
    return list(_FALLBACKS)


def reset_explain() -> None:
    _FALLBACKS.clear()
