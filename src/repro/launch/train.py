"""Federated training driver (multi-local-step, node-stacked GeoLoRA).

The full protocol at mesh scale: node-private trainables carry a leading
node axis sharded over the mesh batch axes; E local steps run with ZERO
cross-node communication (vmap over the node axis — each mesh slice
advances its own B_k / m_k); each round ends with the server step
(consensus Gram + precision-weighted averaging), whose collective footprint
is low-rank-sized — the paper's communication-efficiency claim, measurable
here with --report-comm.

  PYTHONPATH=src python -m repro.launch.train --arch fedmm-small \
      --rounds 3 --local-steps 4 --batch 8 --seq 128 --tiny
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import aggregation as agg
from repro.core import cka as cka_mod
from repro.core import lora as lora_mod
from repro.core import uncertainty as unc
from repro.data.pipeline import SyntheticLMStream
from repro.models import transformer as T
from repro.models.common import cross_entropy_loss
from repro.optim.adamw import AdamW


def _broadcast_tree(tree, k):
    return jax.tree.map(
        lambda x: None if x is None else
        jnp.broadcast_to(x, (k,) + x.shape).copy(), tree,
        is_leaf=lambda x: x is None)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedmm-small")
    ap.add_argument("--method", default="geodora",
                    choices=["geolora", "geodora", "fedavg_full"])
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)     # per node
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--anchors", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lambda-geo", type=float, default=1.0)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the model for CPU smoke runs")
    ap.add_argument("--precision-weighting", action="store_true",
                    default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        head_dim=32, d_ff=256, vocab_size=512,
                        dtype="float32")
    k_nodes = args.nodes
    key = jax.random.PRNGKey(0)
    rt = T.Runtime()

    params = T.init_params(key, cfg)
    if args.method != "fedavg_full":
        spec = lora_mod.LoRASpec(rank=args.rank,
                                 dora=(args.method == "geodora"))
        params = lora_mod.attach_lora(jax.random.fold_in(key, 1), params,
                                      spec)
        mask = lora_mod.trainable_mask(params)
    else:
        mask = jax.tree.map(lambda _: True, params)
    trainable, frozen = lora_mod.partition(params, mask)
    opt = AdamW(lr=args.lr, grad_clip=1.0)

    node_train = _broadcast_tree(trainable, k_nodes)
    node_opt = jax.vmap(opt.init)(node_train)
    anchors = jax.random.randint(jax.random.fold_in(key, 2),
                                 (args.anchors, args.seq), 0, cfg.vocab_size)

    def local_step(train_k, opt_k, batch, gbar):
        def loss_fn(tr):
            p = lora_mod.combine(tr, frozen)
            logits, aux = T.forward(p, {"tokens": batch["tokens"]}, cfg, rt)
            task = cross_entropy_loss(logits, batch["labels"])
            _, a_aux = T.forward(p, {"tokens": anchors}, cfg, rt)
            gram = cka_mod.cosine_gram(a_aux["pooled"])
            geo = 1.0 - cka_mod.cka(gram, gbar)
            u = unc.lap_uncertainty(aux["pooled"], a_aux["pooled"])
            return task + args.lambda_geo * geo, \
                (task, geo, gram, unc.node_precision(u))
        grads, (task, geo, gram, prec) = jax.grad(loss_fn, has_aux=True)(
            train_k)
        new_train, new_opt = opt.update(grads, opt_k, train_k)
        return new_train, new_opt, task, geo, gram, prec

    vstep = jax.jit(jax.vmap(local_step, in_axes=(0, 0, 0, None)))

    streams = [iter(SyntheticLMStream(cfg.vocab_size, args.seq, args.batch,
                                      seed=100 + i)) for i in range(k_nodes)]
    gbar = jnp.eye(args.anchors)
    t0 = time.time()
    for rnd in range(args.rounds):
        for step_i in range(args.local_steps):
            batch = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[next(s) for s in streams])
            node_train, node_opt, task, geo, grams, prec = vstep(
                node_train, node_opt, batch, gbar)
        # ---- server: consensus Gram + precision-weighted averaging ----
        gbar = grams.mean(axis=0)
        w = (unc.precision_weights(prec) if args.precision_weighting
             else jnp.full((k_nodes,), 1.0 / k_nodes))
        avg = jax.tree.map(
            lambda x: None if x is None else
            jnp.tensordot(w.astype(jnp.float32), x.astype(jnp.float32),
                          axes=1).astype(x.dtype),
            node_train, is_leaf=lambda x: x is None)
        node_train = _broadcast_tree(avg, k_nodes)
        node_opt = jax.vmap(opt.init)(node_train)

        up_bytes = lora_mod.param_bytes(avg) + args.anchors ** 2 * 4
        full_bytes = lora_mod.param_bytes(
            lora_mod.combine(trainable, frozen))
        print(f"round {rnd}: task={float(task.mean()):.4f} "
              f"geo={float(geo.mean()):.4f} "
              f"w={[round(float(x), 3) for x in w]} "
              f"uplink={up_bytes/1e6:.3f}MB vs full {full_bytes/1e6:.1f}MB "
              f"({100 * (1 - up_bytes / full_bytes):.2f}% saved) "
              f"[{time.time()-t0:.0f}s]", flush=True)
    return float(task.mean())


if __name__ == "__main__":
    main()
