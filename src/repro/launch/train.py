"""Federated LM training driver on the shared node-stacked round engine.

The full protocol at mesh scale, built on ``repro.core.engine.RoundEngine``
— the same engine that powers ``repro.core.federation.Federation``: node
trainables/opt-states carry a leading node axis, E local steps run as a
scanned vmap with ZERO cross-node communication, and each round closes with
the server step (consensus Gram + LAP precision weighting + side-car
averaging) inside the SAME compiled call.

With ``--block-size M > 1`` the driver fuses M whole rounds into one
donated dispatch (``engine.run_block``: lax.scan over the round body):
batches for a block are leaf-stacked host-side into one (M, E, K, B, S)
tensor and shipped as a single async transfer, the NEXT block's batches are
staged while the current block is in flight (double buffering), and
per-round metrics stream back through an ``io_callback`` tap — the host
never blocks between blocks, so dispatches and blocking syncs drop to 1/M
per round.  ``--block-size 1`` is the exact legacy per-round path;
``--block-size auto`` measures the host dispatch overhead once at startup
(the first two rounds run per-round and are timed) and picks M so host
work stays under 5% of round time.  ``--server-momentum`` enables
FedOpt-style momentum on the averaged side-cars in the engine's server
step.  ``--warmup-rounds N`` turns on a warmup+cosine LR schedule keyed on
the GLOBAL round counter the engine threads through the scan carry, so the
schedule advances across fused blocks without re-jitting.

Partial participation (``--participation uniform --cohort-size C``,
``--participation dropout --dropout-rate p``, or ``precision``): each
round's reporting cohort is sampled ON DEVICE from a carried sampler
state, so sampling composes with the fused blocks; non-reporting nodes
carry their state through untouched and the server averages over exactly
the cohort.  Communication per round is low-rank-sized — the paper's
efficiency claim, printed per round.

  PYTHONPATH=src python -m repro.launch.train --arch fedmm-small \
      --rounds 8 --block-size 4 --local-steps 4 --batch 8 --seq 128 \
      --participation uniform --cohort-size 2 --tiny
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cka as cka_mod
from repro.core import lora as lora_mod
from repro.core import participation as part_mod
from repro.core.engine import EngineConfig, RoundEngine, auto_block_size
from repro.data.pipeline import BlockStager, SyntheticLMStream
from repro.models import transformer as T
from repro.models.common import cross_entropy_loss
from repro.optim.adamw import AdamW, warmup_cosine


def _broadcast_tree(tree, k):
    return jax.tree.map(
        lambda x: None if x is None else
        jnp.broadcast_to(x, (k,) + x.shape).copy(), tree,
        is_leaf=lambda x: x is None)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedmm-small")
    ap.add_argument("--method", default="geodora",
                    choices=["geolora", "geodora", "fedavg_full"])
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)     # per node
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--anchors", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lambda-geo", type=float, default=1.0)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--block-size", default="1",
                    help="fuse M rounds per dispatch (1 = legacy "
                         "per-round; 'auto' measures dispatch overhead at "
                         "startup and picks M for < 5%% host work)")
    ap.add_argument("--server-momentum", type=float, default=None,
                    help="server-side FedOpt momentum on the averaged "
                         "side-cars (off when unset)")
    ap.add_argument("--participation", default="full",
                    choices=["full", "uniform", "precision", "dropout",
                             "async"],
                    help="per-round cohort sampling strategy ('async' "
                         "turns on the buffered staleness-aware protocol: "
                         "nodes report after a sampled lag, may crash and "
                         "rejoin, and the server staleness-weights "
                         "whatever landed this round)")
    ap.add_argument("--cohort-size", type=int, default=None,
                    help="nodes sampled per round (uniform / precision)")
    ap.add_argument("--dropout-rate", type=float, default=0.25,
                    help="per-node straggler probability (dropout)")
    ap.add_argument("--participation-seed", type=int, default=0)
    ap.add_argument("--lag-dist", default="fixed",
                    choices=["fixed", "geometric"],
                    help="async: per-report lag distribution")
    ap.add_argument("--lag", type=int, default=1,
                    help="async: fixed lag in rounds (lag 0 = deliver "
                         "the same round, i.e. synchronous timing)")
    ap.add_argument("--lag-p", type=float, default=0.5,
                    help="async: geometric lag success probability")
    ap.add_argument("--max-lag", type=int, default=4,
                    help="async: lag draws are clipped to this many rounds")
    ap.add_argument("--crash-rate", type=float, default=0.0,
                    help="async: per-round probability an online node "
                         "crashes (losing its in-flight report)")
    ap.add_argument("--rejoin-rate", type=float, default=0.5,
                    help="async: per-round probability a crashed node "
                         "rejoins")
    ap.add_argument("--transient-rate", type=float, default=0.0,
                    help="async: per-round probability an idle node "
                         "transiently fails to start a report")
    ap.add_argument("--staleness", default="poly",
                    choices=["poly", "cutoff"],
                    help="async: staleness schedule on report weights "
                         "(poly: (1+lag)^-alpha; cutoff: hard drop past "
                         "--max-staleness)")
    ap.add_argument("--staleness-alpha", type=float, default=1.0,
                    help="async: exponent of the poly staleness schedule")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="async: reports older than this many rounds get "
                         "zero aggregation weight")
    ap.add_argument("--quarantine-norm", type=float, default=1e6,
                    help="async: reports with non-finite values or an "
                         "update norm above this are quarantined (zero "
                         "contribution, per-node counter bumped)")
    ap.add_argument("--poison-nodes", default="",
                    help="async fault injection: comma-separated node ids "
                         "whose reports are corrupted to NaN on device "
                         "(exercises the quarantine guard)")
    ap.add_argument("--warmup-rounds", type=int, default=0,
                    help="> 0 turns on warmup+cosine LR over GLOBAL "
                         "rounds (threaded through the fused-block carry)")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the model for CPU smoke runs")
    ap.add_argument("--precision-weighting", action="store_true",
                    default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        head_dim=32, d_ff=256, vocab_size=512,
                        dtype="float32")
    k_nodes = args.nodes
    key = jax.random.PRNGKey(0)
    rt = T.Runtime()

    params = T.init_params(key, cfg)
    if args.method != "fedavg_full":
        spec = lora_mod.LoRASpec(rank=args.rank,
                                 dora=(args.method == "geodora"))
        params = lora_mod.attach_lora(jax.random.fold_in(key, 1), params,
                                      spec)
        mask = lora_mod.trainable_mask(params)
    else:
        mask = jax.tree.map(lambda _: True, params)
    trainable, frozen = lora_mod.partition(params, mask)
    round_sched = (warmup_cosine(args.warmup_rounds, max(args.rounds, 1))
                   if args.warmup_rounds > 0 else None)
    opt = AdamW(lr=args.lr, grad_clip=1.0, round_schedule=round_sched)
    poison = tuple(int(x) for x in args.poison_nodes.split(",") if x.strip())
    plan = part_mod.normalize(part_mod.ParticipationPlan(
        strategy=args.participation, cohort_size=args.cohort_size,
        dropout_rate=args.dropout_rate, seed=args.participation_seed,
        lag_dist=args.lag_dist, lag=args.lag, lag_p=args.lag_p,
        max_lag=args.max_lag, crash_rate=args.crash_rate,
        rejoin_rate=args.rejoin_rate, transient_rate=args.transient_rate,
        staleness=args.staleness, staleness_alpha=args.staleness_alpha,
        max_staleness=args.max_staleness,
        quarantine_norm=args.quarantine_norm, poison_nodes=poison))

    anchors = jax.random.randint(jax.random.fold_in(key, 2),
                                 (args.anchors, args.seq), 0, cfg.vocab_size)
    lambda_geo = args.lambda_geo

    def local_step(train_k, opt_k, key_k, gbar, _statics, batch):
        def loss_fn(tr):
            p = lora_mod.combine(tr, frozen)
            logits, aux = T.forward(p, {"tokens": batch["tokens"]}, cfg, rt)
            task = cross_entropy_loss(logits, batch["labels"])
            _, a_aux = T.forward(p, {"tokens": anchors}, cfg, rt)
            gram = cka_mod.cosine_gram(a_aux["pooled"])
            geo = 1.0 - cka_mod.cka(gram, gbar)
            return task + lambda_geo * geo, \
                (task, geo, aux["pooled"], a_aux["pooled"])
        grads, (task, geo, pooled, pooled_a) = \
            jax.grad(loss_fn, has_aux=True)(train_k)
        new_train, new_opt = opt.update(grads, opt_k, train_k)
        return new_train, new_opt, key_k, {
            "task": task, "geo": geo,
            "pooled": pooled, "pooled_a": pooled_a}

    # LM nodes have no node-local adapters: every trainable leaf is shipped
    # and every node shares one width — a single engine bucket
    shipped = jax.tree.map(lambda p: None if p is None else True,
                           trainable, is_leaf=lambda x: x is None)
    engine = RoundEngine(
        EngineConfig(n_nodes=k_nodes, local_steps=args.local_steps,
                     aggregation=("precision" if args.precision_weighting
                                  else "uniform"),
                     server_momentum=args.server_momentum),
        opt, local_step, (shipped,))

    node_train = (_broadcast_tree(trainable, k_nodes),)
    node_opt = (jax.vmap(opt.init)(node_train[0]),)
    node_keys = (jax.random.split(jax.random.fold_in(key, 3), k_nodes),)
    gbar = jnp.eye(args.anchors)
    server_m = engine.init_server_state(node_train)

    part_state = (engine.init_async_state(node_train, plan,
                                          gram_side=args.anchors)
                  if plan is not None and plan.strategy == "async"
                  else part_mod.init_state(plan, k_nodes))
    streams = [iter(SyntheticLMStream(cfg.vocab_size, args.seq, args.batch,
                                      seed=100 + i)) for i in range(k_nodes)]
    up_bytes = lora_mod.param_bytes(trainable) + args.anchors ** 2 * 4
    full_bytes = lora_mod.param_bytes(lora_mod.combine(trainable, frozen))
    t0 = time.time()
    rnd_counter = [0]

    def cohort_of(metrics, r=None):
        if "cohort_size" not in metrics:
            return k_nodes
        c = metrics["cohort_size"] if r is None else metrics["cohort_size"][r]
        return max(int(round(float(c))), 1)

    def round_task(metrics, r=None):
        t = (metrics["scalars"]["task"] if r is None
             else metrics["scalars"]["task"][r])
        return float(jnp.sum(t)) / cohort_of(metrics, r)

    def log_round(metrics):
        rnd = rnd_counter[0]
        rnd_counter[0] += 1
        scalars, c = metrics["scalars"], cohort_of(metrics)
        cohort = f" cohort={c}/{k_nodes}" if "cohort_size" in metrics else ""
        if "n_delivered" in metrics:
            qs = [int(round(float(x))) for x in metrics["quarantined"]]
            cohort += (f" delivered={float(metrics['n_delivered']):.0f}"
                       + (f" quarantined={qs}" if any(qs) else ""))
        print(f"round {rnd}: task={float(jnp.sum(scalars['task']))/c:.4f} "
              f"geo={float(jnp.sum(scalars['geo']))/c:.4f} "
              f"xcka={float(metrics['cross_node_cka']):.3f} "
              f"w={[round(float(x), 3) for x in metrics['weights']]}"
              f"{cohort} "
              f"uplink={up_bytes/1e6:.3f}MB vs full {full_bytes/1e6:.1f}MB "
              f"({100 * (1 - up_bytes / full_bytes):.2f}% saved) "
              f"[{time.time()-t0:.0f}s]", flush=True)

    def stage_round():
        step_batches = []
        for _ in range(args.local_steps):
            per_node = [next(s) for s in streams]
            step_batches.append(jax.tree.map(
                lambda *xs: jnp.stack(xs), *per_node))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *step_batches)

    # round state as a mutable list so the per-round and fused paths share
    # it (the participation sampler state rides along when a plan is on)
    state = [node_train, node_opt, node_keys, gbar, server_m]
    if plan is not None:
        state.append(part_state)
    round_fn = engine.part_round_fn(plan) if plan else engine.round_fn

    def run_one(batches):
        out = round_fn(*state, (None,), (batches,))
        state[:] = out[:-1]
        return out[-1]

    auto = str(args.block_size) == "auto"
    block_size = 1 if auto else int(args.block_size)
    last_metrics = None
    rounds_left = args.rounds
    if rounds_left <= 0:
        return 0.0
    if auto:
        # measure ONCE at startup: round 0 pays compilation (warmup),
        # round 1 times the async dispatch (host work) vs the full round,
        # and M is picked so host work < 5% of round time under M-blocks
        last_metrics = run_one(stage_round())
        log_round(last_metrics)
        rounds_left -= 1
        if rounds_left > 0:
            batches = stage_round()
            t0m = time.perf_counter()
            last_metrics = run_one(batches)
            t_dispatch = time.perf_counter() - t0m
            jax.block_until_ready(last_metrics)
            t_round = time.perf_counter() - t0m
            block_size = auto_block_size(t_dispatch, t_round)
            print(f"[auto] dispatch={t_dispatch*1e3:.2f}ms "
                  f"round={t_round*1e3:.2f}ms -> block size M={block_size}",
                  flush=True)
            log_round(last_metrics)
            rounds_left -= 1
    if rounds_left > 0 and block_size <= 1:
        # legacy per-round path: one dispatch and one host sync per round
        for _ in range(rounds_left):
            last_metrics = run_one(stage_round())
            log_round(last_metrics)
        final_task = round_task(last_metrics)
    elif rounds_left > 0:
        # fused blocks: M rounds per donated dispatch, metrics streamed via
        # the io_callback tap, next block's batches staged while the current
        # block is in flight — no block_until_ready anywhere in the loop
        stager = BlockStager(streams, args.local_steps, block_size)
        next_batches = stager.next_block(min(block_size, rounds_left))
        while rounds_left > 0:
            m = min(block_size, rounds_left)
            batches = next_batches
            new_state, last_metrics = engine.run_block(
                tuple(state), m, statics=(None,), batches=(batches,),
                tap=log_round, plan=plan)
            state[:] = list(new_state)
            rounds_left -= m
            if rounds_left > 0:         # double buffer: stage block N+1
                next_batches = stager.next_block(
                    min(block_size, rounds_left))
        # the ONLY host sync of the whole run: materialise the last round's
        # task loss, then drain the tap callbacks (metric readback alone
        # does not wait for the io_callback thread — without the barrier
        # the last round's log lines can be lost at process exit)
        final_task = round_task(last_metrics, r=-1)
        jax.effects_barrier()
    else:
        final_task = round_task(last_metrics)
    return final_task


if __name__ == "__main__":
    main()
