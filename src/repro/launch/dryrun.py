import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory / cost / collective data.

  PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-nemo-12b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --resume

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline table (EXPERIMENTS.md section Roofline) is generated from them by
benchmarks/roofline_table.py.
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config)  # noqa: E402
from repro.core import lora as lora_mod                               # noqa: E402
from repro.launch import input_specs as ispec                         # noqa: E402
from repro.launch import mesh as mesh_mod                             # noqa: E402
from repro.launch import shardings as shd                             # noqa: E402
from repro.launch import steps as steps_mod                           # noqa: E402
from repro.models import transformer as T                             # noqa: E402
from repro.optim.adamw import AdamW                                   # noqa: E402
from repro.roofline import analysis as roof                           # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _shardings(tree_specs, mesh):
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
        tree_specs, is_leaf=lambda x: isinstance(x, P))


def lower_one(arch: str, shape_name: str, mesh_kind: str,
              extra_tag: str = "", rt_override=None, lora_dora: bool = True,
              rt_patch: dict = None, layout: str = "tp"):
    """Returns (record, compiled) — compiled kept for ad-hoc inspection."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = ispec.skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}, None

    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rt = rt_override or ispec.runtime_for(cfg, shape, mesh)
    if layout in ("dp", "fsdp_dp"):
        import dataclasses as _dc
        all_axes = tuple(mesh.shape.keys())
        rt = _dc.replace(rt, seq_shard=False, batch_axes=all_axes)
    if rt_patch:
        import dataclasses as _dc
        rt = _dc.replace(rt, **rt_patch)
    shd.reset_explain()
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            spec = lora_mod.LoRASpec(rank=16, dora=lora_dora)
            params = ispec.abstract_params(cfg, spec)
            mask = lora_mod.trainable_mask(params)
            trainable, frozen = lora_mod.partition(params, mask)
            opt = AdamW(lr=1e-4)
            opt_state = jax.eval_shape(opt.init, trainable)
            batch, bspecs, gbar = ispec.train_batch_specs(
                cfg, shape, mesh,
                data_axes=tuple(mesh.shape.keys())
                if layout in ("dp", "fsdp_dp") else None)
            from jax.sharding import PartitionSpec as P
            pspecs = shd.param_specs(
                params, mesh, {"fsdp_dp": "fsdp"}.get(layout, layout))
            t_specs, f_specs = lora_mod.partition(pspecs, mask)
            o_specs = {"m": t_specs, "v": t_specs, "step": P()}
            step = steps_mod.make_fed_train_step(
                cfg, rt, opt, k_nodes=mesh_mod.n_nodes(mesh))
            in_shardings = (
                _shardings(t_specs, mesh), _shardings(f_specs, mesh),
                _shardings(o_specs, mesh), _shardings(bspecs, mesh),
                _shardings(P(), mesh))
            args = (trainable, frozen, opt_state, batch, gbar)
            donate = (0, 2)          # trainable, opt_state updated in place
        elif shape.kind == "prefill":
            params = ispec.abstract_params(cfg)
            batch, bspecs = ispec.serve_batch_specs(cfg, shape, mesh)
            pspecs = shd.param_specs(params, mesh)
            step = steps_mod.make_prefill_step(cfg, rt)
            in_shardings = (_shardings(pspecs, mesh),
                            _shardings(bspecs, mesh))
            args = (params, batch)
            donate = ()
        else:  # decode
            params = ispec.abstract_params(cfg)
            batch, bspecs = ispec.serve_batch_specs(cfg, shape, mesh)
            cache = ispec.abstract_cache(cfg, shape, rt)
            cspecs = shd.cache_specs(cache, mesh)
            pspecs = shd.param_specs(params, mesh)
            step = steps_mod.make_decode_step(cfg, rt)
            in_shardings = (_shardings(pspecs, mesh),
                            _shardings(cspecs, mesh),
                            _shardings(bspecs, mesh))
            args = (params, cache, batch)
            donate = (1,)            # cache updated in place

        lowered = jax.jit(step, in_shardings=in_shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mf = roof.model_flops(cfg, shape, training=(shape.kind == "train"))
    rl = roof.roofline_from_compiled(compiled, n_chips=n_chips,
                                     model_flops_global=mf)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "tag": extra_tag,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "fallbacks": shd.explain(),
        "roofline": rl.to_dict(),
    }
    print(compiled.memory_analysis())
    return rec, compiled


def result_path(arch, shape, mesh_kind, tag=""):
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = result_path(arch, shape, mesh_kind, args.tag)
                if args.resume and os.path.exists(path):
                    continue
                t0 = time.time()
                try:
                    rec, _ = lower_one(arch, shape, mesh_kind, args.tag)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                with open(path, "w") as fh:
                    json.dump(rec, fh, indent=1)
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_fail += status == "error"
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']} "
                             f"c={r['compute_s']*1e3:.1f}ms "
                             f"m={r['memory_s']*1e3:.1f}ms "
                             f"x={r['collective_s']*1e3:.1f}ms")
                elif status == "error":
                    extra = rec["error"][:120]
                print(f"[{status:7s}] {arch} {shape} {mesh_kind} "
                      f"({time.time()-t0:.0f}s) {extra}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
