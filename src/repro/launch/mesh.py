"""Production mesh builders (TPU v5e pods; host-device placeholders in the
dry-run container).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; smoke tests see
one device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh (CPU smoke tests of the sharded code paths)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_abstract_mesh(shape, axes):
    """Version-compat AbstractMesh constructor.

    jax >= 0.5 takes ``AbstractMesh(axis_sizes, axis_names)``; jax <= 0.4.x
    takes a single tuple of ``(name, size)`` pairs.  Abstract meshes carry
    only shape/name information — exactly what the sharding rule engine and
    its tests need without touching device state."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def n_nodes(mesh) -> int:
    """Federated nodes = slices along the batch axes (one node per slice)."""
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out


# TPU v5e hardware constants (per chip) used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,      # FLOP/s
    "hbm_bw": 819e9,                # B/s
    "ici_bw": 50e9,                 # B/s per link
    "hbm_bytes": 16 * 2 ** 30,
}
