"""Three-term roofline from a compiled dry-run artifact (no hardware).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16, v5e)
  memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
  collective = collective_operand_bytes_per_device / ICI_bw (~50 GB/s/link)

``cost_analysis()`` on the partitioned executable yields per-device FLOPs /
bytes.  Collective bytes are NOT in cost_analysis: we stream the optimized
HLO text and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (documented assumption: each device
pushes roughly its operand-size bytes through its ICI links; ring-algorithm
constant factors ~2(n-1)/n are absorbed into the link-bandwidth figure).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

HW = {
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
    "hbm_bytes": 16 * 2 ** 30,
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# wire-traffic factor on the RESULT size (ring algorithms, per device):
#   all-reduce moves ~2x its buffer; gather/scatter/permute ~1x.
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_RESULT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(\([^=]*\))?\s*->")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result: str) -> int:
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(result))


def _split_computations(hlo_text):
    """computation name -> list of instruction lines."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                tok = stripped.split()
                name = tok[1] if tok[0] == "ENTRY" else tok[0]
                cur = name.lstrip("%")
                comps[cur] = []
            continue
        if stripped == "}":
            cur = None
        else:
            comps[cur].append(line)
    return comps


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes per collective kind from partitioned scheduled
    HLO.  Structural parse: collectives inside while (layer-scan) bodies are
    multiplied by the loop trip count (read from the condition computation's
    comparison constant); nested scans compose multiplicatively."""
    comps = _split_computations(hlo_text)

    def cond_trip(cond_name):
        best = 1
        for line in comps.get(cond_name, ()):  # largest constant in the cond
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
        return best

    info = {}
    details = {}
    for name, lines in comps.items():
        own = {k: 0.0 for k in _COLLECTIVES}
        counts = {k: 0 for k in _COLLECTIVES}
        calls = []
        for line in lines:
            m = _RESULT_RE.match(line)
            if m:
                op = m.group(2)
                base = op[:-6] if op.endswith("-start") else op
                if base in _COLLECTIVES and not op.endswith("-done"):
                    nb = _WIRE_FACTOR[base] * _result_bytes(m.group(1))
                    own[base] += nb
                    counts[base] += 1
                    om = re.search(r'op_name="([^"]+)"', line)
                    details.setdefault(name, []).append(
                        (base, nb, m.group(1)[:60],
                         om.group(1)[-90:] if om else ""))
            if m and m.group(2) == "while":
                cond = body = None
                for cm in re.finditer(r"(condition|body)=%?([\w.\-]+)", line):
                    if cm.group(1) == "condition":
                        cond = cm.group(2)
                    else:
                        body = cm.group(2)
                if body in comps:
                    calls.append((body, cond_trip(cond) if cond else 1))
            else:
                for called in _CALLED_RE.findall(line):
                    if called in comps:
                        calls.append((called, 1))
        info[name] = (own, counts, calls)

    entry = None
    for name in comps:          # ENTRY holds "main" in jitted modules
        if "main" in name:
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    totals = {k: 0.0 for k in _COLLECTIVES}
    counts_total = {k: 0 for k in _COLLECTIVES}
    stack = []

    def walk(name, mult):
        if name not in info or name in stack:
            return
        stack.append(name)
        own, counts, calls = info[name]
        for k in _COLLECTIVES:
            totals[k] += mult * own[k]
            counts_total[k] += counts[k]
        for callee, m in calls:
            walk(callee, mult * m)
        stack.pop()

    # attribute per-instruction bytes x loop multiplicity
    contrib = []
    mults = {}

    def walk2(name, mult):
        if name not in info or name in stack:
            return
        stack.append(name)
        mults[name] = mults.get(name, 0.0) + mult
        for callee, m in info[name][2]:
            walk2(callee, mult * m)
        stack.pop()

    walk2(entry, 1.0)
    for cname, items in details.items():
        mult = mults.get(cname, 0.0)
        if mult <= 0:
            continue
        for base, nb, shape, opname in items:
            contrib.append((nb * mult, base, shape, f"x{int(mult)}", opname))
    contrib.sort(reverse=True)

    walk(entry, 1.0)
    out = {k: int(v) for k, v in totals.items()}
    out["_counts"] = counts_total
    out["_top"] = [
        {"bytes": int(b), "kind": k, "shape": sh, "mult": mu, "op": op}
        for b, k, sh, mu, op in contrib[:12]]
    return out




# ----------------------------------------------------------------------
# Structural FLOP / byte counting.  XLA's cost_analysis() counts while-loop
# bodies ONCE, undercounting scanned (layers) programs by ~L x.  We re-count
# from the scheduled HLO with the same call-graph walk as the collectives:
#   flops: 2 * prod(result dims) * contraction size, for every dot in every
#          computation reached from ENTRY (fusion bodies included),
#          multiplied by enclosing while trip counts;
#   bytes: operands + results of instructions in ENTRY/while bodies only
#          (fusion internals stay on-chip, which is the point of fusion).
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_shape(result: str):
    m = _SHAPE_RE.search(result)
    if not m:
        return None, 0
    dt, dims = m.group(1), m.group(2)
    shape = [int(d) for d in dims.split(",")] if dims else []
    return shape, _shape_bytes(dt, dims)


def structural_cost(hlo_text: str):
    """Returns (flops, bytes_accessed) with loop-trip multipliers."""
    comps = _split_computations(hlo_text)

    def cond_trip(cond_name):
        best = 1
        for line in comps.get(cond_name, ()):
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
        return best

    # per computation: symbols, flops, bytes, calls
    info = {}
    for name, lines in comps.items():
        sym = {}
        flops = 0.0
        nbytes = 0.0
        calls = []     # (callee, trip, kind) kind: 'loop'|'call'
        for line in lines:
            m = _RESULT_RE.match(line)
            if not m:
                continue
            res_name = line.split("=")[0].strip().lstrip("%").split()[-1] \
                if "=" in line else ""
            # robust: first token before '='
            res_name = line.strip().split("=")[0].strip() \
                .lstrip("ROOT").strip().lstrip("%")
            shape, rbytes = _parse_shape(m.group(1))
            sym[res_name] = (shape, rbytes)
            op = m.group(2)
            if op == "dot":
                cm = _DOT_CONTRACT_RE.search(line)
                args = _OPERAND_RE.findall(line[m.end():])
                lhs = sym.get(args[0], (None, 0))[0] if args else None
                csize = 1
                if cm and lhs:
                    for idx in (int(i) for i in cm.group(1).split(",")
                                if i != ""):
                        if idx < len(lhs):
                            csize *= lhs[idx]
                if shape is not None:
                    n = 1
                    for d in shape:
                        n *= d
                    flops += 2.0 * n * csize
            if op == "while":
                cond = body = None
                for c in re.finditer(r"(condition|body)=%?([\w.\-]+)", line):
                    if c.group(1) == "condition":
                        cond = c.group(2)
                    else:
                        body = c.group(2)
                if body in comps:
                    calls.append((body, cond_trip(cond) if cond else 1,
                                  "loop"))
            elif op == "fusion" or "calls=" in line or "to_apply=" in line:
                for called in _CALLED_RE.findall(line):
                    if called in comps:
                        calls.append((called, 1, "call"))
            # bytes: result + operands (names resolved in this computation)
            opers = _OPERAND_RE.findall(line[m.end():line.find("metadata")
                                              if "metadata" in line
                                              else len(line)])
            obytes = sum(sym.get(a, (None, 0))[1] for a in opers)
            nbytes += rbytes + obytes
        info[name] = (flops, nbytes, calls)

    entry = None
    for name in comps:
        if "main" in name:
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    total = {"flops": 0.0, "bytes": 0.0}
    stack = []

    def walk(name, mult, count_bytes):
        if name not in info or name in stack:
            return
        stack.append(name)
        flops, nbytes, calls = info[name]
        total["flops"] += mult * flops
        if count_bytes:
            total["bytes"] += mult * nbytes
        for callee, trip, kind in calls:
            walk(callee, mult * trip, count_bytes and kind == "loop")
        stack.pop()

    walk(entry, 1.0, True)
    return total["flops"], total["bytes"]


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float
    useful_flops_ratio: float
    memory_stats: Optional[dict] = None

    def to_dict(self):
        return asdict(self)


def roofline_from_compiled(compiled, *, n_chips: int,
                           model_flops_global: float = 0.0,
                           hw: dict = HW) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    s_flops, s_bytes = structural_cost(txt)
    # XLA cost_analysis counts while (scan) bodies ONCE — undercounting
    # layer-scanned programs by ~n_layers. The structural dot-counter
    # multiplies by trip counts; its FLOPs are trustworthy. Raw structural
    # BYTES over-count (every instruction = HBM traffic, no fusion), so the
    # memory estimate scales XLA's own bytes-accessed by the loop-undercount
    # factor measured on FLOPs (the loops dominate both).
    flops = max(xla_flops, s_flops)
    trip_factor = max(1.0, s_flops / xla_flops) if xla_flops else 1.0
    nbytes = xla_bytes * trip_factor
    coll = parse_collectives(txt)
    coll_bytes = float(sum(v for k, v in coll.items() if k in _COLLECTIVES))
    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = nbytes / hw["hbm_bw"]
    collective_s = coll_bytes / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_global / max(n_chips, 1)
    mem_stats = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem_stats = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
    except Exception:
        pass
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_bytes_per_device=coll_bytes,
        collectives=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_per_device=mf,
        useful_flops_ratio=(mf / flops) if flops else 0.0,
        memory_stats=mem_stats,
    )


def decode_cache_bytes_per_slot(cfg, cache_len: int) -> float:
    """HBM bytes ONE slot's decode-state read costs per decode step.

    Attention families re-read the slot's whole KV window every token;
    recurrent families re-read a fixed-size state.  Matches the pool
    layout in ``serve.pool`` / ``models.transformer.init_cache``:

      GQA   : 2 * n_kv * head_dim * min(cache_len, window) per layer
      MLA   : (kv_lora_rank + rope_head_dim) * cache_len per layer
      SSM   : d_inner * (state_dim + conv_kernel) per layer
      hybrid: RG-LRU state for recurrent layers, SWA ring for attention
    """
    b = _DTYPE_BYTES.get({"float32": "f32", "bfloat16": "bf16",
                          "float16": "f16"}.get(cfg.dtype, cfg.dtype), 2)
    d = cfg.d_model
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        return cfg.n_layers * d_in * (s.state_dim + s.conv_kernel) * b
    if cfg.family == "hybrid":
        r = cfg.rglru
        w = r.lru_width or d
        pat = r.block_pattern
        n_att = sum(1 for i in range(cfg.n_layers)
                    if pat[i % len(pat)] == "attention")
        n_rec = cfg.n_layers - n_att
        ring = min(cache_len, r.local_window)
        att = n_att * 2 * cfg.n_kv_heads * cfg.head_dim * ring
        rec = n_rec * w * (1 + r.conv_kernel)
        return (att + rec) * b
    if cfg.mla is not None:
        m = cfg.mla
        return cfg.n_layers * (m.kv_lora_rank + m.rope_head_dim) \
            * cache_len * b
    window = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
        else cache_len
    return cfg.n_layers * 2 * cfg.n_kv_heads * cfg.head_dim * window * b


def decode_roofline(cfg, *, n_slots: int, cache_len: int,
                    hw: dict = HW) -> dict:
    """Memory-bound serving prediction for a batched decode step.

    Decode at serving batch sizes is HBM-bandwidth bound: each step
    streams every (active) weight once — amortised over the S slots —
    plus each slot's decode state.  Returns predicted per-step seconds,
    per-token milliseconds, and tokens/s at full occupancy; the serving
    benchmark reports these next to measured throughput so the gap
    (dispatch overhead, host scheduling, CPU-vs-TPU) is visible.
    """
    b = _DTYPE_BYTES.get({"float32": "f32", "bfloat16": "bf16",
                          "float16": "f16"}.get(cfg.dtype, cfg.dtype), 2)
    param_bytes = cfg.active_param_count * b
    slot_bytes = decode_cache_bytes_per_slot(cfg, cache_len)
    step_bytes = param_bytes + n_slots * slot_bytes
    step_s = step_bytes / hw["hbm_bw"]
    return {
        "param_bytes": int(param_bytes),
        "cache_bytes_per_slot": int(slot_bytes),
        "step_bytes": int(step_bytes),
        "bytes_per_token": int(step_bytes / max(n_slots, 1)),
        "pred_step_s": step_s,
        "pred_ms_per_token": 1e3 * step_s / max(n_slots, 1),
        "pred_tokens_per_s": n_slots / step_s if step_s else float("inf"),
    }


def model_flops(cfg, shape, *, training: bool) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE); D = tokens processed.  Decode
    processes global_batch tokens per step (one each)."""
    n = cfg.active_param_count
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d           # fwd+bwd
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch
