import os

# Smoke tests must see the real (1-device) CPU — never the dry-run's 512
# placeholder devices (see launch/dryrun.py which sets this itself).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run XLA_FLAGS globally"

import jax

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (multi-device subprocess)")
