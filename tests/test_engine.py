"""Engine equivalence: the node-stacked single-dispatch round engine must
reproduce the sequential per-node reference (same RNG streams, padded-width
adapters, static corrupt/bridge/synthetic branch masks)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.federation import (Federation, FederationConfig,
                                   SequentialFederation)

TINY = get_config("fedmm-small").with_(
    n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    d_ff=64, vocab_size=128, dtype="float32")

# small-width modalities keep the padded program cheap in CI
BASE = dict(n_nodes=4, rounds=2, local_steps=2, local_batch=8,
            modalities=("genetics", "tabular"), bridge_modality="tabular",
            anchors_per_class=2, n_tokens=4, lora_rank=4)


def _assert_histories_close(hs, he, tol=1e-4):
    assert len(hs) == len(he)
    for a, b in zip(hs, he):
        for k in ("task_loss", "geo_loss", "acc", "cross_node_cka"):
            np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                       err_msg=k)
        np.testing.assert_allclose(a["weights"], b["weights"], atol=tol)
        assert a["uplink_bytes"] == b["uplink_bytes"]
        assert a["full_model_bytes"] == b["full_model_bytes"]


def test_engine_matches_sequential_plain():
    fed = FederationConfig(method="geolora", aggregation="precision", **BASE)
    hs = SequentialFederation(fed, TINY).run()
    he = Federation(fed, TINY).run()
    _assert_histories_close(hs, he)


def test_engine_matches_sequential_hetero_nodes():
    """Bridge + corrupt + synthetic-anchor nodes under GeoDoRA: one padded
    program with static branch masks must still match the reference, which
    runs a different jitted step per node type."""
    fed = FederationConfig(method="geodora", aggregation="precision",
                           bridge_nodes=(0,), corrupt_nodes=(2,),
                           synthetic_anchor_nodes=(3,), **BASE)
    seq = SequentialFederation(fed, TINY)
    eng = Federation(fed, TINY)
    _assert_histories_close(seq.run(), eng.run())
    # per-node views keep the reference's ragged structure
    assert "adapter2" in eng.nodes[0]["trainable"]
    assert "adapter2" not in eng.nodes[1]["trainable"]
    for i, node in enumerate(eng.nodes):
        d = eng.tokenizers[node["modality"]].d_out
        assert node["trainable"]["adapter"]["w"].shape[0] == d


def test_round_is_single_jitted_call(monkeypatch):
    """The engine's whole round (E local epochs + server step) must be ONE
    compiled program: traced exactly once across rounds, with the
    sequential per-node jitted steps provably never dispatched."""
    from repro.core import engine as engine_mod

    traces = {"n": 0}
    orig_round = engine_mod.RoundEngine._round

    def counting_round(self, *args, **kw):
        traces["n"] += 1                 # fires once per jit TRACE only
        return orig_round(self, *args, **kw)

    def boom(*args, **kw):
        raise AssertionError("sequential per-node jit step dispatched")

    monkeypatch.setattr(engine_mod.RoundEngine, "_round", counting_round)
    monkeypatch.setattr(SequentialFederation, "_local_step", boom)
    monkeypatch.setattr(SequentialFederation, "_bridge_step", boom)

    fed = FederationConfig(method="geolora", **BASE)
    f = Federation(fed, TINY)
    r0, r1 = f.run_round(), f.run_round()
    # the whole round — local epochs AND server step — is one jaxpr,
    # compiled once and re-dispatched; no per-node Python-loop stepping
    assert traces["n"] == 1
    assert np.isfinite(r0["task_loss"]) and np.isfinite(r1["task_loss"])


def test_shard_map_path_matches_vmap_path():
    """mesh= maps the node axis onto the mesh batch axes via shard_map; on
    the 1-device local mesh it must agree with the plain vmapped engine."""
    from repro.launch.mesh import make_local_mesh
    fed = FederationConfig(method="geolora", rounds=1, corrupt_nodes=(1,),
                           **{k: v for k, v in BASE.items()
                              if k != "rounds"})
    ha = Federation(fed, TINY).run()
    hb = Federation(fed, TINY, mesh=make_local_mesh()).run()
    _assert_histories_close(ha, hb, tol=1e-5)
