"""Engine equivalence: the node-stacked single-dispatch round engine must
reproduce the sequential per-node reference (same RNG streams, padded-width
adapters, static corrupt/bridge/synthetic branch masks)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.federation import (Federation, FederationConfig,
                                   SequentialFederation)

TINY = get_config("fedmm-small").with_(
    n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    d_ff=64, vocab_size=128, dtype="float32")

# small-width modalities keep the padded program cheap in CI
BASE = dict(n_nodes=4, rounds=2, local_steps=2, local_batch=8,
            modalities=("genetics", "tabular"), bridge_modality="tabular",
            anchors_per_class=2, n_tokens=4, lora_rank=4)


def _assert_histories_close(hs, he, tol=1e-4):
    assert len(hs) == len(he)
    for a, b in zip(hs, he):
        for k in ("task_loss", "geo_loss", "acc", "cross_node_cka"):
            np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                       err_msg=k)
        np.testing.assert_allclose(a["weights"], b["weights"], atol=tol)
        assert a["uplink_bytes"] == b["uplink_bytes"]
        assert a["full_model_bytes"] == b["full_model_bytes"]


def test_engine_matches_sequential_plain():
    fed = FederationConfig(method="geolora", aggregation="precision", **BASE)
    hs = SequentialFederation(fed, TINY).run()
    he = Federation(fed, TINY).run()
    _assert_histories_close(hs, he)


def test_engine_matches_sequential_hetero_nodes():
    """Bridge + corrupt + synthetic-anchor nodes under GeoDoRA: one padded
    program with static branch masks must still match the reference, which
    runs a different jitted step per node type."""
    fed = FederationConfig(method="geodora", aggregation="precision",
                           bridge_nodes=(0,), corrupt_nodes=(2,),
                           synthetic_anchor_nodes=(3,), **BASE)
    seq = SequentialFederation(fed, TINY)
    eng = Federation(fed, TINY)
    _assert_histories_close(seq.run(), eng.run())
    # per-node views keep the reference's ragged structure
    assert "adapter2" in eng.nodes[0]["trainable"]
    assert "adapter2" not in eng.nodes[1]["trainable"]
    for i, node in enumerate(eng.nodes):
        d = eng.tokenizers[node["modality"]].d_out
        assert node["trainable"]["adapter"]["w"].shape[0] == d


def test_round_is_single_jitted_call(monkeypatch):
    """The engine's whole round (E local epochs + server step) must be ONE
    compiled program: traced exactly once across rounds, with the
    sequential per-node jitted steps provably never dispatched."""
    from repro.core import engine as engine_mod

    traces = {"n": 0}
    orig_round = engine_mod.RoundEngine._round

    def counting_round(self, *args, **kw):
        traces["n"] += 1                 # fires once per jit TRACE only
        return orig_round(self, *args, **kw)

    def boom(*args, **kw):
        raise AssertionError("sequential per-node jit step dispatched")

    monkeypatch.setattr(engine_mod.RoundEngine, "_round", counting_round)
    monkeypatch.setattr(SequentialFederation, "_local_step", boom)
    monkeypatch.setattr(SequentialFederation, "_bridge_step", boom)

    fed = FederationConfig(method="geolora", **BASE)
    f = Federation(fed, TINY)
    r0, r1 = f.run_round(), f.run_round()
    # the whole round — local epochs AND server step — is one jaxpr,
    # compiled once and re-dispatched; no per-node Python-loop stepping
    assert traces["n"] == 1
    assert np.isfinite(r0["task_loss"]) and np.isfinite(r1["task_loss"])


def test_shard_map_path_matches_vmap_path():
    """mesh= maps each bucket's node axis onto the mesh batch axes via
    shard_map; on the 1-device local mesh it must agree with the plain
    vmapped engine."""
    from repro.launch.mesh import make_local_mesh
    fed = FederationConfig(method="geolora", rounds=1, corrupt_nodes=(1,),
                           **{k: v for k, v in BASE.items()
                              if k != "rounds"})
    ha = Federation(fed, TINY).run()
    hb = Federation(fed, TINY, mesh=make_local_mesh()).run()
    _assert_histories_close(ha, hb, tol=1e-5)


# ----------------------------------------------------------------------
# width bucketing: the full 4-modality mix (192..2048-dim tokenizers)
MIXED = dict(n_nodes=4, rounds=2, local_steps=2, local_batch=8,
             modalities=("image", "text", "genetics", "tabular"),
             anchors_per_class=2, n_tokens=4, lora_rank=4)


def test_bucket_layout_mixed_width():
    """4 modalities -> one node each -> 4 distinct widths; a bridge node's
    width is the max of its two adapters, moving it into the text bucket.
    The stable permutation concatenates buckets in ascending width."""
    fed = FederationConfig(method="geolora", bridge_nodes=(0,),
                           bridge_modality="text", **MIXED)
    f = Federation(fed, TINY)
    # node0 image+text bridge -> 2048; node1 text -> 2048; node2 genetics
    # -> 768; node3 tabular -> 192
    assert f._bucket_widths == (192, 768, 2048)
    assert f._buckets == ((3,), (2,), (0, 1))
    assert f.engine.ecfg.node_perm == (3, 2, 0, 1)
    # per-bucket adapters are padded to the BUCKET width, not d_max
    assert f._trains[0]["adapter"]["w"].shape == \
        (1, 192, TINY.d_model)
    assert f._trains[2]["adapter"]["w"].shape == \
        (2, 2048, TINY.d_model)


def test_bucketed_engine_matches_sequential_mixed_width():
    """Oracle equivalence on the heterogeneous-width regime the paper
    targets: image/text/genetics/tabular nodes with corrupt + bridge +
    synthetic-anchor heterogeneity, run as W=3 width buckets inside one
    compiled round, must reproduce the sequential per-node reference."""
    fed = FederationConfig(method="geodora", aggregation="precision",
                           bridge_nodes=(0,), bridge_modality="text",
                           corrupt_nodes=(2,), synthetic_anchor_nodes=(3,),
                           **MIXED)
    hs = SequentialFederation(fed, TINY).run()
    he = Federation(fed, TINY).run()
    _assert_histories_close(hs, he)


def test_bucketed_matches_padded_engine():
    """width_bucketing=False restores the legacy pad-to-max-width single
    bucket; both layouts must produce the same history (zero-padding is
    exact, bucketing only removes dead padded compute)."""
    fed = FederationConfig(method="geolora", corrupt_nodes=(1,), **MIXED)
    hb = Federation(fed, TINY).run()
    hp = Federation(fed, TINY, width_bucketing=False).run()
    # measured gap is ~1e-7..3e-6; the suite-standard 1e-4 leaves headroom
    # for XLA codegen variation in the 2048-wide padded matmuls
    _assert_histories_close(hb, hp)
    f = Federation(fed, TINY, width_bucketing=False)
    assert f._buckets == ((0, 1, 2, 3),)
    assert f._bucket_widths == (2048,)


def test_mesh_unshardable_buckets_fall_back_to_padded_layout():
    """A mesh whose shard count divides K but not every bucket (e.g. one
    node per width on a 2-slice mesh) must fall back to the single
    pad-to-max bucket instead of rejecting a config the pre-bucketing
    engine accepted; a 1-slice mesh keeps the bucketed layout."""
    fed = FederationConfig(method="geolora", **MIXED)
    f = Federation(fed, TINY)                     # no mesh: 4 buckets of 1
    widths = [f._node_width(n) for n in f.nodes]
    assert len(f._buckets) == 4

    class FakeMesh:
        shape = {"data": 2, "model": 1}

    bw, buckets = f._bucket_layout(widths, FakeMesh())
    assert bw == (2048,) and buckets == [tuple(range(4))]

    class OneSlice:
        shape = {"data": 1, "model": 1}

    bw1, buckets1 = f._bucket_layout(widths, OneSlice())
    assert len(buckets1) == 4 and bw1 == f._bucket_widths


def test_round_state_buffers_are_donated():
    """donate_argnums: after a round, the PREVIOUS round-state buffers
    (stacked trainables / opt moments / keys / gbar) must be invalidated —
    their memory was reused for the outputs (the halve-peak-memory claim).
    Statics (anchors, tokenizer weights) are NOT donated and stay live."""
    fed = FederationConfig(method="geolora", **BASE)
    f = Federation(fed, TINY)
    old_train = f._trains[0]["cls_head"]["w"]
    old_keys = f._keys[0]
    old_gbar = f.gbar
    anchors = f._staticss[0]["anchors"]
    f.run_round()
    assert old_train.is_deleted() and old_keys.is_deleted()
    assert old_gbar.is_deleted()
    assert not anchors.is_deleted()
    # opt-out: donate=False keeps the inputs alive
    g = Federation(fed, TINY, donate=False)
    keep = g._trains[0]["cls_head"]["w"]
    g.run_round()
    assert not keep.is_deleted()


def test_checkpoint_roundtrip_through_bucket_permutation(tmp_path):
    """Engine checkpoints store the bucketed state; a restore into a fresh
    mixed-width federation must land every node back at its bucket row —
    the next round is identical to the uninterrupted run and the unpadded
    per-node views keep the reference's ragged shapes."""
    import os
    fed = FederationConfig(method="geolora", aggregation="precision",
                           bridge_nodes=(0,), bridge_modality="text",
                           **MIXED)

    f1 = Federation(fed, TINY)
    f1.run_round()
    path = os.path.join(tmp_path, "fed_bucketed.npz")
    f1.save(path)
    r_cont = f1.run_round()

    f2 = Federation(fed, TINY)
    assert f2.restore(path) == 1
    r_resumed = f2.run_round()
    assert abs(r_cont["task_loss"] - r_resumed["task_loss"]) < 1e-5
    assert abs(r_cont["cross_node_cka"] - r_resumed["cross_node_cka"]) < 1e-5
    np.testing.assert_allclose(r_cont["weights"], r_resumed["weights"],
                               atol=1e-6)
    # views go through the permutation and strip the bucket padding
    for i, node in enumerate(f2.nodes):
        d = f2.tokenizers[node["modality"]].d_out
        assert node["trainable"]["adapter"]["w"].shape[0] == d
        for a, b in zip(jax.tree.leaves(f1.nodes[i]["trainable"]),
                        jax.tree.leaves(node["trainable"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
