"""Mixer-level tests: MoE routing/capacity, Mamba + RG-LRU chunked scans."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.ssm import _chunked_diag_scan

KEY = jax.random.PRNGKey(0)


def test_capacity_formula():
    assert moe_mod._capacity(65536, 6, 160, 1.25) == 3072
    assert moe_mod._capacity(2, 2, 4, 1.25) == 2        # floored: no drops
    assert moe_mod._capacity(100, 1, 16, 1.25) == 8     # min floor 8


def test_router_scores_and_aux():
    cfg = reduced(get_config("deepseek-v2-236b"))
    p = moe_mod.make_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    scores, idx, aux = moe_mod.router_scores(p, x, cfg)
    m = cfg.moe
    assert scores.shape == (2, 8, m.num_experts)
    # exactly top_k nonzero scores per token, summing to 1
    nz = (np.asarray(scores) > 0).sum(-1)
    np.testing.assert_array_equal(nz, m.top_k)
    np.testing.assert_allclose(np.asarray(scores).sum(-1), 1.0, atol=1e-5)
    # balanced-uniform router => load_balance ~ 1
    assert 0.5 < float(aux["load_balance"]) < 2.0


def test_moe_matches_dense_expert_sum():
    """With capacity high enough, the gather/scatter path equals the naive
    dense per-expert computation."""
    cfg = reduced(get_config("llama4-scout-17b-a16e"))
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                            num_shared_experts=0))
    p = moe_mod.make_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 6, cfg.d_model))
    y, _ = moe_mod.moe_ffn(p, x, cfg)
    scores, _, _ = moe_mod.router_scores(p, x, cfg)

    def dense(x, scores):
        out = jnp.zeros_like(x)
        for e in range(cfg.moe.num_experts):
            wg = p["experts"]["gate"]["w"][e]
            wu = p["experts"]["up"]["w"][e]
            wd = p["experts"]["down"]["w"][e]
            h = jax.nn.silu(x @ wg) * (x @ wu)
            out = out + (h @ wd) * scores[..., e:e + 1]
        return out
    want = dense(x, scores)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_moe_shared_expert_added():
    cfg = reduced(get_config("deepseek-v2-236b"))
    p = moe_mod.make_moe(KEY, cfg, jnp.float32)
    assert "shared" in p
    x = jax.random.normal(KEY, (1, 4, cfg.d_model))
    y, _ = moe_mod.moe_ffn(p, x, cfg)
    assert y.shape == x.shape


# ----------------------------------------------------------------------
def test_chunked_scan_matches_sequential():
    def seq_scan(da, dbx, h0):
        hs = []
        h = h0
        for t in range(da.shape[1]):
            h = da[:, t] * h + dbx[:, t]
            hs.append(h)
        return jnp.stack(hs, 1), h
    da = jax.random.uniform(KEY, (2, 21, 5), minval=0.2, maxval=0.99)
    dbx = jax.random.normal(KEY, (2, 21, 5))
    h0 = jax.random.normal(KEY, (2, 5))
    for chunk in (4, 7, 21, 64):
        h_all, h_last = _chunked_diag_scan(da, dbx, h0, chunk)
        want_all, want_last = seq_scan(da, dbx, h0)
        np.testing.assert_allclose(np.asarray(h_all), np.asarray(want_all),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(want_last),
                                   rtol=1e-5, atol=1e-6)


def test_mamba_forward_decode_equivalence():
    cfg = reduced(get_config("falcon-mamba-7b"))
    p = ssm_mod.make_mamba(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model))
    y_full, state_full = ssm_mod.mamba_forward(p, x, cfg)
    state = ssm_mod.init_mamba_state(2, cfg, jnp.float32)
    ys = []
    for t in range(12):
        y, state = ssm_mod.mamba_decode(p, x[:, t:t + 1], state, cfg)
        ys.append(y)
    got = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state["h"]),
                               np.asarray(state_full["h"]),
                               rtol=1e-4, atol=1e-4)


def test_mamba_forward_with_state_stitching():
    """Processing a sequence in two halves with carried state == one pass
    (the chunked-prefill invariant)."""
    cfg = reduced(get_config("falcon-mamba-7b"))
    p = ssm_mod.make_mamba(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model))
    y_full, _ = ssm_mod.mamba_forward(p, x, cfg)
    y1, st = ssm_mod.mamba_forward(p, x[:, :8], cfg)
    y2, _ = ssm_mod.mamba_forward(p, x[:, 8:], cfg, h0=st["h"],
                                  conv0=st["conv"])
    got = jnp.concatenate([y1, y2], 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


def test_rglru_forward_decode_equivalence():
    cfg = reduced(get_config("recurrentgemma-9b"))
    p = rglru_mod.make_rglru_block(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 10, cfg.d_model))
    y_full, _ = rglru_mod.rglru_forward(p, x, cfg)
    state = rglru_mod.init_rglru_state(2, cfg, jnp.float32)
    ys = []
    for t in range(10):
        y, state = rglru_mod.rglru_decode(p, x[:, t:t + 1], state, cfg)
        ys.append(y)
    got = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


def test_rglru_stability():
    """RG-LRU gate keeps |a| < 1 => bounded state over long sequences."""
    cfg = reduced(get_config("recurrentgemma-9b"))
    p = rglru_mod.make_rglru_block(KEY, cfg, jnp.float32)
    x = 5.0 * jax.random.normal(KEY, (1, 256, cfg.d_model))
    y, state = rglru_mod.rglru_forward(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(state["h"]).max()) < 1e3
