"""Hypothesis property tests on the system's invariants."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import aggregation as agg
from repro.core import cka as C
from repro.core import uncertainty as U

SET = dict(max_examples=25, deadline=None)


def arrays(shape, elements=st.floats(-3, 3, width=32)):
    return hnp.arrays(np.float32, shape, elements=elements)


# ----------------------------------------------------------------------
# Paper Eq. 4 soundness: with a SHARED frozen A, averaging the B_k factors
# equals averaging the full low-rank updates — exactly.
@settings(**SET)
@given(arrays((3, 4, 6)), arrays((8, 4)))
def test_fixed_a_averaging_linearity(bs, a):
    # B_k: (K=3, r=4, d_out=6); A: (d_in=8, r=4)
    delta_each = np.stack([a @ b for b in bs])       # (3, 8, 6)
    np.testing.assert_allclose(a @ bs.mean(0), delta_each.mean(0),
                               rtol=1e-3, atol=1e-4)


# Counter-property: with per-node A_k (heterogeneous, what FedIT does)
# averaging B_k is NOT equivalent — motivating the frozen shared A.
def test_heterogeneous_a_breaks_averaging():
    rng = np.random.default_rng(0)
    a_k = rng.standard_normal((3, 8, 4)).astype(np.float32)
    b_k = rng.standard_normal((3, 4, 6)).astype(np.float32)
    true_avg = np.mean([a @ b for a, b in zip(a_k, b_k)], axis=0)
    naive = a_k.mean(0) @ b_k.mean(0)
    assert np.abs(true_avg - naive).max() > 0.1


@settings(**SET)
@given(arrays((6, 5)))
def test_cka_bounds(x):
    g = np.asarray(C.cosine_gram(jnp.asarray(x) + 1e-3))
    v = float(C.cka(g, g))
    assert 0.999 <= v <= 1.001


@settings(**SET)
@given(arrays((7, 4)), st.floats(0.1, 10.0))
def test_gram_sample_scale_invariance(x, s):
    """Cosine kernel kills per-sample magnitude — the paper's motivation for
    aligning direction not magnitude."""
    x = x + 0.1  # avoid zero rows
    g1 = np.asarray(C.cosine_gram(jnp.asarray(x)))
    g2 = np.asarray(C.cosine_gram(jnp.asarray(x * s)))
    np.testing.assert_allclose(g1, g2, atol=1e-4)


@settings(**SET)
@given(arrays((5, 8)), arrays((6, 8)))
def test_lap_uncertainty_bounds(z, a):
    u = np.asarray(U.lap_uncertainty(jnp.asarray(z + 1e-3),
                                     jnp.asarray(a + 1e-3)))
    assert (u >= -1e-6).all() and (u <= 1.0 + 1e-6).all()


def test_lap_anchor_samples_are_certain():
    a = jnp.asarray(np.random.default_rng(1).standard_normal((6, 8)),
                    jnp.float32)
    u = U.lap_uncertainty(a, a)
    assert float(u.max()) < 1e-5


@settings(**SET)
@given(arrays((5,), st.floats(0.01, 100)))
def test_precision_weights_normalised(p):
    w = np.asarray(U.precision_weights(jnp.asarray(p)))
    assert abs(w.sum() - 1.0) < 1e-5
    assert (w >= 0).all()


def test_precision_weights_monotone():
    w = np.asarray(U.precision_weights(jnp.asarray([1.0, 2.0, 4.0])))
    assert w[0] < w[1] < w[2]


@settings(**SET)
@given(arrays((4, 3, 5)))
def test_fedavg_of_identical_is_identity(x):
    trees = [{"w": jnp.asarray(x[0])} for _ in range(4)]
    out = agg.fedavg(trees)
    np.testing.assert_allclose(np.asarray(out["w"]), x[0], atol=1e-5)


@settings(**SET)
@given(arrays((3, 6)))
def test_weighted_mean_extremes(x):
    trees = [{"w": jnp.asarray(x[i])} for i in range(3)]
    w = jnp.asarray([1.0, 0.0, 0.0])
    out = agg.weighted_mean_trees(trees, w)
    np.testing.assert_allclose(np.asarray(out["w"]), x[0], atol=1e-5)
