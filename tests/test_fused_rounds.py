"""Fused multi-round blocks: ``run_block(M)`` (lax.scan over rounds, one
donated dispatch) must reproduce M per-round engine dispatches exactly;
``block_size=1`` stays the legacy path; block-boundary checkpoints resume
bit-identically; the server-FedOpt knob is a no-op when off."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.federation import Federation, FederationConfig

TINY = get_config("fedmm-small").with_(
    n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    d_ff=64, vocab_size=128, dtype="float32")

BASE = dict(n_nodes=4, local_steps=2, local_batch=8,
            modalities=("genetics", "tabular"), bridge_modality="tabular",
            anchors_per_class=2, n_tokens=4, lora_rank=4)

# the paper's heterogeneous-width regime: 4 modalities (192..2048-wide
# tokenizers) over K=8 nodes -> W=3 width buckets (the bridge node joins
# the text bucket)
MIXED_K8 = dict(n_nodes=8, local_steps=2, local_batch=4,
                modalities=("image", "text", "genetics", "tabular"),
                bridge_modality="text", anchors_per_class=2, n_tokens=4,
                lora_rank=4)


def _assert_histories_equal(ha, hb, tol=1e-6, w_tol=None):
    """Losses / accuracy / cross-node CKA at ``tol``; the LAP precision
    weights optionally at ``w_tol``: they are normalised inverse variances,
    so the f32 reduction reassociation XLA applies when the round body is
    compiled inside lax.scan (vs as a standalone program) is amplified
    decades past the raw metric noise (observed up to ~1e-5, varying run
    to run with compile order).  Identical programs are bit-identical
    within a process — the gap is codegen, not logic — so the weights get
    the suite-standard engine-equivalence tolerance (cf. test_engine)."""
    assert len(ha) == len(hb)
    for a, b in zip(ha, hb):
        for k in ("task_loss", "geo_loss", "acc", "cross_node_cka"):
            np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                       err_msg=k)
        np.testing.assert_allclose(a["weights"], b["weights"],
                                   atol=w_tol or tol)


def test_run_block_matches_sequential_rounds_mixed_width_k8():
    """Oracle equivalence (the ISSUE acceptance bar): a fused M-round block
    on the mixed-width bucketed K=8 federation — corrupt + bridge +
    synthetic-anchor nodes included — must match M sequential ``run_round``
    dispatches to <= 1e-6."""
    fed = FederationConfig(method="geodora", aggregation="precision",
                           rounds=2, bridge_nodes=(0,), corrupt_nodes=(2,),
                           synthetic_anchor_nodes=(3,), **MIXED_K8)
    h_seq = Federation(fed, TINY).run()                # M=2 run_round calls
    h_blk = Federation(fed, TINY).run(block_size=2)    # ONE fused dispatch
    _assert_histories_equal(h_seq, h_blk, tol=1e-6, w_tol=1e-4)


def test_block_size_one_is_exact_legacy_path():
    """block_size=1 must never touch the block executor — it is the same
    per-round ``round_fn`` code path as before this feature existed."""
    fed = FederationConfig(method="geolora", rounds=2, **BASE)
    f = Federation(fed, TINY)

    def boom(*a, **kw):
        raise AssertionError("block executor used for block_size=1")

    f.engine.block_fn = boom
    recs = f.run_rounds(2, block_size=1)
    assert len(recs) == 2 and len(f.history) == 2
    assert all(np.isfinite(r["task_loss"]) for r in recs)


def test_block_remainder_and_history():
    """n not divisible by block_size: a final smaller block covers the
    remainder and history records stay per-round."""
    fed = FederationConfig(method="geolora", rounds=3, **BASE)
    f = Federation(fed, TINY)
    recs = f.run_rounds(3, block_size=2)               # blocks of 2 + 1
    assert len(recs) == 3 and len(f.history) == 3
    h_ref = Federation(fed, TINY).run_rounds(3, block_size=1)
    _assert_histories_equal(h_ref, recs)


def test_block_tap_streams_per_round_metrics():
    """The io_callback tap fires once per ROUND (not per block) with that
    round's metrics, in order, without the driver syncing between blocks."""
    fed = FederationConfig(method="geolora", rounds=4, **BASE)
    f = Federation(fed, TINY)
    taps = []
    recs = f.run_rounds(4, block_size=2,
                        tap=lambda m: taps.append(
                            float(np.mean(m["scalars"]["task"]))))
    assert len(taps) == 4
    np.testing.assert_allclose(taps, [r["task_loss"] for r in recs],
                               atol=1e-6)


def test_checkpoint_at_block_boundary_bit_identical(tmp_path):
    """A checkpoint written at a block boundary is the engine's block carry:
    restoring it and running the next block must be BIT-identical to the
    uninterrupted blocked run (same compiled function, same inputs)."""
    import os
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=4, bridge_nodes=(0,), **BASE)
    f1 = Federation(fed, TINY)
    f1.run_rounds(2, block_size=2)
    path = os.path.join(tmp_path, "fed_block.npz")
    f1.save(path)
    rec_cont = f1.run_rounds(2, block_size=2)

    f2 = Federation(fed, TINY)
    assert f2.restore(path) == 2
    rec_resumed = f2.run_rounds(2, block_size=2)
    for a, b in zip(rec_cont, rec_resumed):
        assert a["task_loss"] == b["task_loss"]
        assert a["cross_node_cka"] == b["cross_node_cka"]
        assert a["weights"] == b["weights"]
    for x, y in zip(jax.tree.leaves((f1._trains, f1._opts, f1._keys,
                                     f1.gbar)),
                    jax.tree.leaves((f2._trains, f2._opts, f2._keys,
                                     f2.gbar))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_server_fedopt_off_is_legacy_and_zero_beta_matches():
    """The FedOpt knob: default (None) carries no server-opt state; an
    ENABLED knob with beta=0 runs the momentum code path but must reduce to
    the plain precision-weighted average; beta>0 must actually differ."""
    fed_off = FederationConfig(method="geolora", rounds=3, **BASE)
    fed_zero = FederationConfig(method="geolora", rounds=3,
                                server_momentum=0.0, **BASE)
    fed_mom = FederationConfig(method="geolora", rounds=3,
                               server_momentum=0.9, **BASE)
    f_off = Federation(fed_off, TINY)
    assert f_off._server_m is None
    h_off = f_off.run()
    f_zero = Federation(fed_zero, TINY)
    assert f_zero._server_m is not None
    h_zero = f_zero.run()
    _assert_histories_equal(h_off, h_zero, tol=1e-5)
    h_mom = Federation(fed_mom, TINY).run()
    assert all(np.isfinite(r["task_loss"]) for r in h_mom)
    assert abs(h_mom[-1]["task_loss"] - h_off[-1]["task_loss"]) > 1e-7


def test_fedopt_state_checkpoints_and_guards_mismatch(tmp_path):
    """server_m rides the checkpointed block carry; restoring into a
    federation with a different server_momentum config fails loudly."""
    import os
    fed = FederationConfig(method="geolora", rounds=2,
                           server_momentum=0.9, **BASE)
    f1 = Federation(fed, TINY)
    f1.run_rounds(2, block_size=2)
    path = os.path.join(tmp_path, "fed_mom.npz")
    f1.save(path)
    f2 = Federation(fed, TINY)
    assert f2.restore(path) == 2
    for x, y in zip(jax.tree.leaves(f1._server_m),
                    jax.tree.leaves(f2._server_m)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    f3 = Federation(
        FederationConfig(method="geolora", rounds=2, **BASE), TINY)
    with pytest.raises(ValueError, match="server_momentum"):
        f3.restore(path)


def test_grams_of_is_one_vmapped_pallas_call():
    """The per-node Gram loop is vectorised: the pallas backend must trace
    to a SINGLE (vmapped) pallas_call over the node axis, not K unrolled
    calls — and match the reference backend."""
    from repro.core.engine import EngineConfig, RoundEngine
    k, ba, dm = 5, 8, 16
    pooled_a = jax.random.normal(jax.random.PRNGKey(0), (k, ba, dm))
    pal = RoundEngine(
        EngineConfig(n_nodes=k, local_steps=1, gram_backend="pallas"),
        None, lambda *a: None, ({},))
    ref = RoundEngine(
        EngineConfig(n_nodes=k, local_steps=1, gram_backend="reference"),
        None, lambda *a: None, ({},))
    np.testing.assert_allclose(np.asarray(pal._grams_of(pooled_a)),
                               np.asarray(ref._grams_of(pooled_a)),
                               atol=1e-5)
    jaxpr = str(jax.make_jaxpr(pal._grams_of)(pooled_a))
    assert jaxpr.count("pallas_call") == 1
