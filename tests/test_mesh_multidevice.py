"""shard_map on a REAL multi-device mesh (ROADMAP open item): 8 host
devices forced via XLA_FLAGS, the 2-axis ("pod", "data") production mesh
topology, per-bucket all_gather metric ordering checked against the
single-device engine.

Runs in a subprocess because the parent pytest process has already
initialised jax with one device."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax
assert jax.device_count() == 8, f"expected 8 forced devices, got {jax.device_count()}"
import numpy as np
from repro.configs import get_config
from repro.core.federation import Federation, FederationConfig
from repro.launch.mesh import batch_axes, n_nodes

# the production topology's batch axes: nodes sharded over pod x data
mesh = jax.make_mesh((2, 4), ("pod", "data"))
assert batch_axes(mesh) == ("pod", "data") and n_nodes(mesh) == 8

TINY = get_config("fedmm-small").with_(
    n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    d_ff=64, vocab_size=128, dtype="float32")
fed = FederationConfig(
    n_nodes=16, rounds=2, local_steps=1, local_batch=4, method="geolora",
    modalities=("genetics", "tabular"), corrupt_nodes=(3,),
    anchors_per_class=1, n_tokens=2, lora_rank=2)

# two width buckets (768 / 192) of 8 nodes each -> 1 node per mesh slice;
# metrics are gathered per BUCKET then concatenated, so any shard-major
# interleave would permute the per-node weights below
f_mesh = Federation(fed, TINY, mesh=mesh)
assert len(f_mesh._buckets) == 2 and all(len(b) == 8 for b in f_mesh._buckets)
h_mesh = f_mesh.run()
h_ref = Federation(fed, TINY).run()
for a, b in zip(h_ref, h_mesh):
    np.testing.assert_allclose(a["weights"], b["weights"], atol=1e-5)
    for k in ("task_loss", "geo_loss", "acc", "cross_node_cka"):
        np.testing.assert_allclose(a[k], b[k], atol=1e-5, err_msg=k)

# fused block on the multi-device mesh: scan over the shard_map round body
h_blk = Federation(fed, TINY, mesh=mesh).run(block_size=2)
for a, b in zip(h_ref, h_blk):
    np.testing.assert_allclose(a["weights"], b["weights"], atol=1e-5)
    np.testing.assert_allclose(a["task_loss"], b["task_loss"], atol=1e-5)

# participation on the real mesh: every shard draws the replicated cohort
# and slices its own rows (linearised pod x data shard index) — must match
# the single-device engine's cohort AND metrics
from repro.core.federation import ParticipationPlan
plan = ParticipationPlan(strategy="uniform", cohort_size=6, seed=2)
h_pr = Federation(fed, TINY).run_rounds(2, participation=plan)
h_pm = Federation(fed, TINY, mesh=mesh).run_rounds(2, participation=plan)
for a, b in zip(h_pr, h_pm):
    assert a["participation"] == b["participation"], (a, b)
    np.testing.assert_allclose(a["weights"], b["weights"], atol=1e-5)
    np.testing.assert_allclose(a["task_loss"], b["task_loss"], atol=1e-5)
    np.testing.assert_allclose(a["cross_node_cka"], b["cross_node_cka"],
                               atol=1e-5)
print("MESH8_OK")
"""


@pytest.mark.slow
def test_shard_map_on_8_device_pod_data_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MESH8_OK" in proc.stdout
