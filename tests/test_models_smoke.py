"""Per-architecture smoke tests (reduced configs per the brief: <=2 layers,
d_model<=512, <=4 experts): one forward + one train step on CPU, asserting
output shapes and finiteness; plus prefill+decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.core import lora as lora_mod
from repro.models import transformer as T
from repro.models.common import cross_entropy_loss
from repro.optim.adamw import AdamW

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _cfg(arch):
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:   # exactness needs no token dropping
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _batch(cfg, s=S, b=B, with_labels=False):
    k1, k2 = jax.random.split(KEY)
    batch = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k2, (b, cfg.n_image_tokens, cfg.image_embed_dim))
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            k2, (b, cfg.encoder_seq_len, cfg.encoder_embed_dim))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _cfg(arch)
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = T.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert aux["pooled"].shape == (B, cfg.d_model)
    assert bool(jnp.isfinite(aux["pooled"].astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_no_nans(arch):
    """One GeoLoRA train step: loss finite, side-car grads flow, params
    update without NaNs — the paper's technique on every backbone."""
    cfg = _cfg(arch)
    params = T.init_params(KEY, cfg)
    params = lora_mod.attach_lora(jax.random.fold_in(KEY, 1), params,
                                  lora_mod.LoRASpec(rank=4, dora=True))
    mask = lora_mod.trainable_mask(params)
    trainable, frozen = lora_mod.partition(params, mask)
    batch = _batch(cfg, with_labels=True)

    def loss_fn(tr):
        p = lora_mod.combine(tr, frozen)
        logits, aux = T.forward(p, batch, cfg)
        return cross_entropy_loss(logits, batch["labels"]) \
            + 0.01 * (aux["load_balance"] + aux["router_z"])

    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    assert bool(jnp.isfinite(loss))
    gleaves = [g for g in jax.tree.leaves(grads) if g is not None]
    assert all(bool(jnp.isfinite(g).all()) for g in gleaves)
    # at least one lora_B gradient is non-zero (technique engaged)
    gnorm = sum(float(jnp.abs(g).sum()) for g in gleaves)
    assert gnorm > 0
    opt = AdamW(lr=1e-3)
    new_tr, _ = opt.update(grads, opt.init(trainable), trainable)
    assert all(bool(jnp.isfinite(l).all())
               for l in jax.tree.leaves(new_tr) if l is not None)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = T.init_params(KEY, cfg)
    s = 16
    toks = jax.random.randint(KEY, (B, s + 1), 0, cfg.vocab_size)
    extra = _batch(cfg)
    extra.pop("tokens")
    full = {"tokens": toks, **extra}
    pre = {"tokens": toks[:, :s], **extra}
    logits_full, _ = T.forward(params, full, cfg)
    _, cache = T.prefill(params, pre, cfg,
                         cache_len=s + cfg.n_image_tokens + 8)
    logits_dec, cache2 = T.decode_step(params, cache,
                                       {"tokens": toks[:, s:s + 1]}, cfg)
    err = float(jnp.abs(logits_full[:, -1].astype(jnp.float32)
                        - logits_dec[:, 0].astype(jnp.float32)).max())
    assert err < 1e-3, f"prefill+decode mismatch {err}"
    assert int(cache2["len"]) == s + cfg.n_image_tokens \
        * (cfg.family == "vlm") + 1


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "recurrentgemma-9b"])
def test_recurrent_decode_is_constant_memory(arch):
    """SSM/hybrid decode state must not grow with sequence length."""
    cfg = _cfg(arch)
    rt = T.Runtime()
    c1 = T.init_cache(cfg, 1, 1024, rt)
    c2 = T.init_cache(cfg, 1, 65536, rt)
    def total(c):
        return sum(x.size for x in jax.tree.leaves(c))
    if cfg.family == "ssm":
        assert total(c1) == total(c2)
    else:  # hybrid: only the local-attention window scales, capped at window
        assert total(c2) <= total(c1) * (cfg.rglru.local_window // 64 + 2)


def test_sliding_window_variant_cache_capped():
    cfg = _cfg("mistral-nemo-12b")
    rt = T.Runtime(window_override=64)
    c = T.init_cache(cfg, 1, 100000, rt)
    assert c["k"].shape[2] == 64      # ring buffer, not 100k
