"""Continuous-batching engine tests.

The load-bearing property is BIT-IDENTITY: a request decoded greedily
through the slot-stacked engine — admitted mid-decode, sharing blocks
with strangers, re-using a slot someone else stopped in — must produce
exactly the tokens the legacy per-token loop produces for that request
alone.  Dispatch structure (one compiled call + one readback per M-step
block) is MEASURED from engine counters, not assumed.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import transformer as T
from repro.serve import (Request, ServeConfig, ServeEngine, gather_slot,
                         init_pool_cache, naive_generate, poisson_requests,
                         scatter_slot)

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return get_config("fedmm-small").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    return cfg, T.init_params(KEY, cfg)


def _oracle(params, cfg, reqs, scfg, stats=None):
    """Isolated legacy runs: batch=1 per request (no head-of-line
    coupling), the ground truth the engine must reproduce exactly."""
    one = dataclasses.replace(scfg, n_slots=1)
    return naive_generate(params, cfg, reqs, one, stats=stats)


def test_streamed_admission_matches_isolated_naive(tiny):
    """Requests streaming into a smaller slot pool — admissions land
    mid-decode, slots get re-used — decode bit-identically to isolated
    per-request legacy loops."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=3, cache_len=64, block_steps=4,
                       max_new_tokens=10)
    reqs = poisson_requests(7, 0.0, prompt_len=8,
                            vocab_size=cfg.vocab_size, seed=11)
    # stagger arrivals so later requests are admitted between blocks,
    # into slots vacated by finished requests
    reqs = [dataclasses.replace(r, arrival_s=0.02 * i)
            for i, r in enumerate(reqs)]
    eng = ServeEngine(params, cfg, scfg)
    recs = eng.serve(reqs)
    want = _oracle(params, cfg, reqs, scfg)
    for r in reqs:
        assert recs[r.rid].tokens == want[r.rid].tokens, r.rid
    assert all(len(recs[r.rid].tokens) == 10 for r in reqs)
    # more requests than slots forces at least one slot re-use
    assert len({recs[r.rid].slot for r in reqs}) <= scfg.n_slots


def test_stop_token_truncates_and_frees_slot(tiny):
    """A stop token truncates exactly where the legacy loop stops, and
    the freed slot is handed to a queued request."""
    cfg, params = tiny
    base = ServeConfig(n_slots=2, cache_len=64, block_steps=4,
                       max_new_tokens=12)
    reqs = poisson_requests(5, 0.0, prompt_len=6,
                            vocab_size=cfg.vocab_size, seed=5)
    free = ServeEngine(params, cfg, base).serve(reqs)
    # pick a token some request emits mid-stream as the stop token
    stop = next(free[r.rid].tokens[3] for r in reqs
                if len(set(free[r.rid].tokens)) > 1)
    scfg = dataclasses.replace(base, stop_token=int(stop))
    recs = ServeEngine(params, cfg, scfg).serve(reqs)
    want = _oracle(params, cfg, reqs, scfg)
    truncated = 0
    for r in reqs:
        got = recs[r.rid].tokens
        assert got == want[r.rid].tokens, r.rid
        if int(stop) in got:
            assert got.index(int(stop)) == len(got) - 1  # nothing after
            truncated += len(got) < 12
    assert truncated >= 1, "stop token never fired; test is vacuous"


def test_per_slot_budgets(tiny):
    """Per-request max_new overrides run side by side in one pool."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=4, cache_len=64, block_steps=4,
                       max_new_tokens=9)
    reqs = poisson_requests(4, 0.0, prompt_len=8,
                            vocab_size=cfg.vocab_size, seed=2)
    reqs = [dataclasses.replace(r, max_new=m)
            for r, m in zip(reqs, (1, 3, 9, None))]
    recs = ServeEngine(params, cfg, scfg).serve(reqs)
    want = _oracle(params, cfg, reqs, scfg)
    assert [len(recs[r.rid].tokens) for r in reqs] == [1, 3, 9, 9]
    for r in reqs:
        assert recs[r.rid].tokens == want[r.rid].tokens, r.rid


def test_block_dispatch_structure(tiny):
    """One compiled call and ONE host readback per M-step block — the
    counters are measured by the engine, not asserted into existence."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=4, cache_len=64, block_steps=8,
                       max_new_tokens=17)
    reqs = poisson_requests(4, 0.0, prompt_len=8,
                            vocab_size=cfg.vocab_size, seed=7)
    eng = ServeEngine(params, cfg, scfg)
    eng.serve(reqs)
    st = eng.stats
    assert st["block_syncs"] == st["block_dispatches"]
    # 16 decode steps per slot (first token comes from prefill) -> 2 blocks
    assert st["block_dispatches"] == 2
    assert st["block_tokens"] == 4 * 16
    # >= M decoded tokens amortise each dispatch and each readback
    assert st["block_tokens"] / st["block_dispatches"] >= scfg.block_steps
    assert st["request_reads"] == 0  # no per-token (nor per-request) syncs
    # the legacy loop pays per token
    nstats = {}
    naive_generate(params, cfg, reqs, scfg, stats=nstats)
    assert nstats["decode_dispatches"] == 16
    assert nstats["host_syncs"] == 17  # prefill argmax + one per step


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "deepseek-v2-236b",
                                  "recurrentgemma-9b", "falcon-mamba-7b"])
def test_families_match_naive(arch):
    """Sliding-window rings, MLA latents, RG-LRU + SWA hybrids and SSM
    states all stream through the same pool bit-identically."""
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=8.0))
    params = T.init_params(KEY, cfg)
    scfg = ServeConfig(n_slots=3, cache_len=64, block_steps=4,
                       max_new_tokens=8)
    reqs = poisson_requests(5, 0.0, prompt_len=8,
                            vocab_size=cfg.vocab_size, seed=3)
    recs = ServeEngine(params, cfg, scfg).serve(reqs)
    want = _oracle(params, cfg, reqs, scfg)
    for r in reqs:
        assert recs[r.rid].tokens == want[r.rid].tokens, (arch, r.rid)


def test_pallas_decode_backend_matches_reference(tiny):
    """attn_backend='pallas' (interpret mode on CPU) routes slot decode
    through kernels.decode_attention and produces identical tokens."""
    cfg, params = tiny
    reqs = poisson_requests(3, 0.0, prompt_len=8,
                            vocab_size=cfg.vocab_size, seed=1)
    outs = {}
    for backend in ("reference", "pallas"):
        scfg = ServeConfig(n_slots=3, cache_len=64, block_steps=2,
                           max_new_tokens=6, attn_backend=backend)
        recs = ServeEngine(params, cfg, scfg).serve(reqs)
        outs[backend] = {r.rid: recs[r.rid].tokens for r in reqs}
    assert outs["reference"] == outs["pallas"]


def test_scatter_gather_roundtrip(tiny):
    """scatter_slot routes every cache leaf (stacked layers AND hybrid
    tails) to the right slot; gather_slot inverts it."""
    cfg, params = tiny
    pool = init_pool_cache(cfg, 4, 32, T.Runtime())
    batch = {"tokens": jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)}
    _, req = T.prefill(params, batch, cfg, T.Runtime(), cache_len=32)
    pool2 = scatter_slot(pool, req, jnp.asarray(2, jnp.int32))
    back = gather_slot(pool2, jnp.asarray(2, jnp.int32))
    flat_a = jax.tree_util.tree_leaves_with_path(req)
    flat_b = dict(jax.tree_util.tree_leaves_with_path(back))
    for path, leaf in flat_a:
        got = flat_b[path]
        assert got.shape == jnp.shape(leaf), path
        assert bool(jnp.array_equal(jnp.asarray(leaf, jnp.float32),
                                    jnp.asarray(got, jnp.float32))), path
    # untouched slots stayed zero
    other = gather_slot(pool2, jnp.asarray(0, jnp.int32))
    assert int(other["len"]) == 0
