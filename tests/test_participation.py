"""Participation layer: sampled cohorts, straggler masks, masked precision
aggregation.  The bar (ISSUE 5): ``participation=full`` is bit-identical
to the legacy engine; a sampled cohort matches an oracle sequential run
over just the sampled nodes (corrupt + bridge + synthetic nodes included)
at 1e-6; the sampler state rides the fused-block carry and the checkpoint;
and the gather-compact and masked execution paths agree."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import participation as part_mod
from repro.core.federation import (Federation, FederationConfig,
                                   ParticipationPlan, SequentialFederation)

TINY = get_config("fedmm-small").with_(
    n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    d_ff=64, vocab_size=128, dtype="float32")

BASE = dict(n_nodes=4, local_steps=2, local_batch=8,
            modalities=("genetics", "tabular"), bridge_modality="tabular",
            anchors_per_class=2, n_tokens=4, lora_rank=4)

# the acceptance-bar regime: 4 modalities (192..2048-wide tokenizers) over
# K=8 nodes -> 3 width buckets, with corrupt + bridge + synthetic nodes
MIXED_K8 = dict(n_nodes=8, local_steps=2, local_batch=4,
                modalities=("image", "text", "genetics", "tabular"),
                bridge_modality="text", anchors_per_class=2, n_tokens=4,
                lora_rank=4)


def _assert_close(ha, hb, tol=1e-4, w_tol=1e-4, check_part=True):
    """Engine-vs-oracle histories.  Cohort membership is exact; metrics
    get the suite-standard sequential-vs-engine tolerance (cf.
    test_engine): XLA's compile-order-dependent f32 reassociation,
    amplified through AdamW's rsqrt at tiny step counts, moves losses by
    up to ~5e-6 BETWEEN RUNS of the same program — a logic bug (wrong
    cohort, missed broadcast, advanced straggler key) shows at 1e-2+."""
    assert len(ha) == len(hb)
    for a, b in zip(ha, hb):
        for k in ("task_loss", "geo_loss", "acc", "cross_node_cka"):
            np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                       err_msg=k)
        np.testing.assert_allclose(a["weights"], b["weights"], atol=w_tol)
        if check_part:
            assert a.get("participation") == b.get("participation")
            assert a.get("cohort_size") == b.get("cohort_size")


# ----------------------------------------------------------------------
# sampler / masked-primitive units
def test_allocate_cohort_largest_remainder():
    # every bucket keeps >= 1 slot (no node is permanently starved by the
    # static allocation), remainder goes proportionally
    assert part_mod.allocate_cohort(4, (1, 1, 6)) == (1, 1, 2)
    assert part_mod.allocate_cohort(3, (2, 2, 2)) == (1, 1, 1)
    assert part_mod.allocate_cohort(8, (2, 2, 4)) == (2, 2, 4)
    assert part_mod.allocate_cohort(4, (4, 4)) == (2, 2)
    assert part_mod.allocate_cohort(5, (2, 8)) == (1, 4)
    with pytest.raises(ValueError):
        part_mod.allocate_cohort(9, (2, 2, 4))
    with pytest.raises(ValueError):           # C < buckets would starve
        part_mod.allocate_cohort(2, (2, 2, 2))


def test_plan_validation():
    with pytest.raises(ValueError):
        ParticipationPlan(strategy="bogus")
    with pytest.raises(ValueError):
        ParticipationPlan(strategy="uniform")          # no cohort size
    with pytest.raises(ValueError):
        ParticipationPlan(strategy="nodes")            # empty node set
    assert part_mod.normalize("full") is None
    assert part_mod.normalize(None) is None
    assert part_mod.normalize(ParticipationPlan()) is None


def test_masked_primitives_match_dense_oracle():
    from repro.core import aggregation as agg
    from repro.core import cka as cka_mod
    from repro.core import uncertainty as unc
    key = jax.random.PRNGKey(0)
    p = jax.random.uniform(key, (5,)) + 0.1
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
    w = unc.masked_precision_weights(p, mask)
    assert float(w[1]) == 0.0 and float(w[4]) == 0.0
    np.testing.assert_allclose(float(w.sum()), 1.0, atol=1e-6)
    dense = np.asarray(p)[[0, 2, 3]]
    np.testing.assert_allclose(np.asarray(w)[[0, 2, 3]],
                               dense / dense.sum(), atol=1e-6)

    grams = jax.random.normal(jax.random.PRNGKey(1), (5, 3, 3))
    np.testing.assert_allclose(
        np.asarray(cka_mod.consensus_gram(grams, mask=mask)),
        np.asarray(grams)[[0, 2, 3]].mean(0), atol=1e-6)
    np.testing.assert_allclose(
        float(cka_mod.mean_offdiag_cka(grams, mask=mask)),
        float(cka_mod.mean_offdiag_cka(grams[jnp.asarray([0, 2, 3])])),
        atol=1e-6)
    # fewer than two reporters -> no off-diagonal pairs -> 0
    lone = jnp.asarray([0.0, 1.0, 0.0, 0.0, 0.0])
    assert float(cka_mod.mean_offdiag_cka(grams, mask=lone)) == 0.0

    # mask-aware normalisation in the bucketed server step: the broadcast
    # value is the average of exactly the reporting rows
    tree = ({"w": jnp.arange(8.0).reshape(4, 2)},)
    smask = ({"w": True},)
    out = agg.weighted_average_bucketed(
        tree, jnp.full((4,), 0.25), smask, (4,),
        part_mask=jnp.asarray([1.0, 1.0, 0.0, 0.0]))
    np.testing.assert_allclose(
        np.asarray(out[0]["w"]),
        np.broadcast_to(np.asarray([[1.0, 2.0]]), (4, 2)), atol=1e-6)


def test_auto_block_size_formula():
    from repro.core.engine import auto_block_size
    # 1ms dispatch, 100ms round: already < 5% -> M=1
    assert auto_block_size(0.001, 0.1) == 1
    # 5ms dispatch, 10ms round: need M >= 10
    assert auto_block_size(0.005, 0.010) == 10
    # degenerate measurements clamp instead of exploding
    assert auto_block_size(0.005, 0.0) == 64
    assert auto_block_size(0.0, 0.010) == 1
    assert auto_block_size(10.0, 0.001, cap=16) == 16


# ----------------------------------------------------------------------
# engine-level equivalences
def test_full_participation_is_bit_identical_to_legacy():
    """participation=full must be routed onto the UNCHANGED legacy round:
    identical compiled function, so histories are bit-identical and the
    participation cache stays empty."""
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=2, **BASE)
    ha = Federation(fed, TINY).run_rounds(2)
    fb = Federation(fed, TINY)
    hb = fb.run_rounds(2, participation="full")
    assert fb.engine._part_cache == {}
    for a, b in zip(ha, hb):
        assert a["task_loss"] == b["task_loss"]
        assert a["cross_node_cka"] == b["cross_node_cka"]
        assert a["weights"] == b["weights"]
        assert "participation" not in b


def test_sampled_cohort_matches_sequential_oracle_mixed_k8():
    """The acceptance bar: a fused-block run with a sampled cohort (C=4 of
    K=8, mixed-width buckets, corrupt + bridge + synthetic nodes) matches
    the sequential reference over the same sampled nodes at 1e-6 on
    losses/CKA, and the server params agree."""
    fed = FederationConfig(method="geodora", aggregation="precision",
                           rounds=2, bridge_nodes=(0,), corrupt_nodes=(2,),
                           synthetic_anchor_nodes=(3,), **MIXED_K8)
    plan = ParticipationPlan(strategy="uniform", cohort_size=4, seed=11)
    seq = SequentialFederation(fed, TINY)
    h_seq = seq.run_rounds(2, participation=plan)
    eng = Federation(fed, TINY)
    h_eng = eng.run_rounds(2, block_size=2, participation=plan)
    _assert_close(h_seq, h_eng)
    # server params (gbar + the broadcast shipped side-cars) and the
    # node-local adapters line up.  Tolerances are Adam-noise-aware:
    # rsqrt(v) at tiny step counts amplifies e-7 f32 reduction noise into
    # isolated ~1e-4 single-element parameter deviations (observed 1-2
    # elements per 65k, varying run to run with XLA compile order), and
    # gbar inherits ~e-5 of it through the trained activations; a REAL
    # divergence (wrong cohort, missed broadcast, key drift) shows up at
    # 1e-2+ and still fails these bounds
    from repro.core import lora as lora_mod
    np.testing.assert_allclose(np.asarray(seq.gbar), np.asarray(eng.gbar),
                               atol=1e-4)
    for i in range(fed.n_nodes):
        smask = lora_mod.shipped_mask(seq.nodes[i]["trainable"])
        for a, b, s in zip(jax.tree.leaves(seq.nodes[i]["trainable"]),
                           jax.tree.leaves(eng.nodes[i]["trainable"]),
                           jax.tree.leaves(smask)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4 if s else 1e-3)


def test_fixed_nodes_cohort_matches_oracle():
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=2, corrupt_nodes=(1,), **BASE)
    plan = ParticipationPlan(strategy="nodes", nodes=(0, 2, 3))
    h_seq = SequentialFederation(fed, TINY).run_rounds(
        2, participation=plan)
    h_eng = Federation(fed, TINY).run_rounds(2, participation=plan)
    _assert_close(h_seq, h_eng)
    assert h_eng[0]["participation"] == [1.0, 0.0, 1.0, 1.0]
    assert h_eng[0]["cohort_size"] == 3
    # the engine's run_round mirrors the oracle's explicit-cohort hook
    r = Federation(fed, TINY).run_round(participants=(0, 2, 3))
    assert r["participation"] == [1.0, 0.0, 1.0, 1.0]


def test_dropout_stragglers_match_oracle_and_guard():
    """The straggler simulator: per-round masks from the carried RNG match
    the oracle; an (almost-)sure-dropout rate degrades to full
    participation instead of an empty round."""
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=3, **BASE)
    plan = ParticipationPlan(strategy="dropout", dropout_rate=0.5, seed=5)
    h_seq = SequentialFederation(fed, TINY).run_rounds(
        3, participation=plan)
    h_eng = Federation(fed, TINY).run_rounds(3, participation=plan)
    _assert_close(h_seq, h_eng)
    # masks vary across rounds (seed 5 gives a non-constant sequence)
    assert len({tuple(r["participation"]) for r in h_eng}) > 1
    # dropout_rate ~ 1: every draw drops everyone -> guard kicks in
    sure = ParticipationPlan(strategy="dropout", dropout_rate=0.999999,
                             seed=0)
    h = Federation(fed, TINY).run_rounds(1, participation=sure)
    assert h[0]["cohort_size"] == fed.n_nodes


def test_compact_gather_equals_masked_execution():
    """The gather-compact path (compute ~ C) and the masked path (compute
    ~ K, masked updates) are two executions of the same math."""
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=3, corrupt_nodes=(1,), **BASE)
    pc = ParticipationPlan(strategy="uniform", cohort_size=2, seed=7)
    pm = ParticipationPlan(strategy="uniform", cohort_size=2, seed=7,
                           compact=False)
    _assert_close(Federation(fed, TINY).run_rounds(3, participation=pc),
                  Federation(fed, TINY).run_rounds(3, participation=pm))


def test_fused_blocks_and_mesh_match_per_round():
    """Participation composes with the fused-block scan (sampler state in
    the donated carry) and with the shard_map path (replicated sampler,
    per-shard mask slices)."""
    from repro.launch.mesh import make_local_mesh
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=4, **BASE)
    plan = ParticipationPlan(strategy="uniform", cohort_size=2, seed=3)
    h_ref = Federation(fed, TINY).run_rounds(4, participation=plan)
    h_blk = Federation(fed, TINY).run_rounds(4, block_size=2,
                                             participation=plan)
    _assert_close(h_ref, h_blk)
    h_mesh = Federation(fed, TINY, mesh=make_local_mesh()).run_rounds(
        4, participation=plan)
    _assert_close(h_ref, h_mesh, tol=1e-5)


def test_empty_bucket_and_server_momentum():
    """A cohort that leaves a width bucket entirely absent must still
    aggregate (cross-bucket shipped average over the reporting bucket
    only), including under server-side FedAvgM."""
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=2, server_momentum=0.9, **BASE)
    # genetics/tabular alternate per node: nodes (0, 2) are both genetics
    # -> the tabular bucket reports nobody
    plan = ParticipationPlan(strategy="nodes", nodes=(0, 2))
    h = Federation(fed, TINY).run_rounds(2, participation=plan)
    assert all(np.isfinite(r["task_loss"]) for r in h)
    assert h[0]["participation"] == [1.0, 0.0, 1.0, 0.0]
    assert all(np.isfinite(w) for r in h for w in r["weights"])


def test_precision_sampling_polls_corrupt_node_less():
    """Precision-proportional sampling: the node whose data is latent-free
    noise reports lower LAP precision and is sampled less often than the
    clean nodes over a run."""
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=8, corrupt_nodes=(2,), **BASE)
    plan = ParticipationPlan(strategy="precision", cohort_size=2, seed=1)
    h = Federation(fed, TINY).run_rounds(8, participation=plan)
    counts = np.sum([r["participation"] for r in h], axis=0)
    others = [counts[i] for i in range(4) if i != 2]
    assert counts[2] <= min(others), counts
    # every round still fields the full cohort
    assert all(r["cohort_size"] == 2 for r in h)


def test_participation_checkpoint_resumes_sampler_stream(tmp_path):
    """The sampler state rides the checkpointed carry: a restored run
    continues the cohort sequence (and everything else) bit-identically."""
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=4, **BASE)
    plan = ParticipationPlan(strategy="uniform", cohort_size=2, seed=9)
    f1 = Federation(fed, TINY)
    f1.run_rounds(2, block_size=2, participation=plan)
    path = os.path.join(tmp_path, "fed_part.npz")
    f1.save(path)
    rec_cont = f1.run_rounds(2, block_size=2, participation=plan)

    f2 = Federation(fed, TINY)
    assert f2.restore(path) == 2
    rec_resumed = f2.run_rounds(2, block_size=2, participation=plan)
    for a, b in zip(rec_cont, rec_resumed):
        assert a["task_loss"] == b["task_loss"]
        assert a["participation"] == b["participation"]
        assert a["weights"] == b["weights"]


def test_block_tap_carries_round_index():
    """The metrics tap payload now carries its in-block round index (what
    lets the unordered per-host mesh taps be reassembled in order)."""
    fed = FederationConfig(method="geolora", rounds=2, **BASE)
    f = Federation(fed, TINY)
    seen = []
    f.run_rounds(2, block_size=2,
                 tap=lambda m: seen.append(m["round_in_block"]))
    assert seen == [0, 1]


# ----------------------------------------------------------------------
# per-block LR schedules (global round index through the scan carry)
def test_round_schedule_equivalent_across_blocks_and_oracle():
    """AdamW.round_schedule keyed on the carried global-round counter:
    fused M-round blocks match per-round stepping AND the sequential
    reference, and the schedule measurably changes training."""
    from repro.optim.adamw import warmup_cosine
    sched = warmup_cosine(2, 6, floor=0.05)
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=4, round_lr_schedule=sched, **BASE)
    h_seq = SequentialFederation(fed, TINY).run_rounds(4)
    h_per = Federation(fed, TINY).run_rounds(4, block_size=1)
    h_blk = Federation(fed, TINY).run_rounds(4, block_size=4)
    _assert_close(h_seq, h_per, tol=1e-4, check_part=False)
    _assert_close(h_per, h_blk, check_part=False)
    fed_flat = FederationConfig(method="geolora", aggregation="precision",
                                rounds=4, **BASE)
    h_flat = Federation(fed_flat, TINY).run_rounds(4, block_size=4)
    assert abs(h_flat[-1]["task_loss"] - h_blk[-1]["task_loss"]) > 1e-7


def test_round_schedule_checkpoint_guard(tmp_path):
    """round_lr_schedule changes the optimizer carry structure (the
    'round' counter); restoring across the knob must fail loudly, like
    the server_momentum guard."""
    from repro.optim.adamw import warmup_cosine
    fed = FederationConfig(method="geolora", rounds=1,
                           round_lr_schedule=warmup_cosine(1, 4), **BASE)
    f1 = Federation(fed, TINY)
    f1.run_round()
    path = os.path.join(tmp_path, "fed_sched.npz")
    f1.save(path)
    f2 = Federation(FederationConfig(method="geolora", rounds=1, **BASE),
                    TINY)
    with pytest.raises(ValueError, match="round_schedule"):
        f2.restore(path)


def test_round_schedule_composes_with_participation():
    """Skipped nodes must NOT advance their round counter (their next
    participating round sees the right schedule point) — engine vs oracle
    under a sampled cohort with a round schedule."""
    from repro.optim.adamw import warmup_cosine
    sched = warmup_cosine(1, 5, floor=0.1)
    fed = FederationConfig(method="geolora", aggregation="precision",
                           rounds=3, round_lr_schedule=sched, **BASE)
    plan = ParticipationPlan(strategy="uniform", cohort_size=2, seed=4)
    h_seq = SequentialFederation(fed, TINY).run_rounds(
        3, participation=plan)
    h_eng = Federation(fed, TINY).run_rounds(3, participation=plan)
    _assert_close(h_seq, h_eng)
