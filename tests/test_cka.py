"""Gram/CKA math (paper Eqs. 1-2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cka as C


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


def test_gram_diagonal_is_one():
    g = C.cosine_gram(_rand(0, (12, 7)))
    np.testing.assert_allclose(np.diag(g), 1.0, atol=1e-5)


def test_gram_symmetric_and_bounded():
    g = np.asarray(C.cosine_gram(_rand(1, (20, 33))))
    np.testing.assert_allclose(g, g.T, atol=1e-6)
    assert (np.abs(g) <= 1.0 + 1e-5).all()


def test_cka_self_is_one():
    g = C.cosine_gram(_rand(2, (16, 8)))
    assert abs(float(C.cka(g, g)) - 1.0) < 1e-6


def test_cka_symmetric():
    gx = C.cosine_gram(_rand(3, (10, 5)))
    gy = C.cosine_gram(_rand(4, (10, 6)))
    assert abs(float(C.cka(gx, gy)) - float(C.cka(gy, gx))) < 1e-6


def test_cka_orthogonal_invariance():
    """Rotating the embedding space leaves the cosine Gram unchanged —
    the property that lets disjoint modalities align geometrically."""
    x = _rand(5, (14, 14))
    q, _ = jnp.linalg.qr(_rand(6, (14, 14)))
    g1 = C.cosine_gram(x)
    g2 = C.cosine_gram(x @ q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


def test_cka_scale_invariance():
    x = _rand(7, (9, 21))
    g = C.cosine_gram(x)
    assert abs(float(C.cka(g, C.cosine_gram(3.7 * x))) - 1.0) < 1e-5


def test_geo_loss_zero_at_consensus():
    x = _rand(8, (8, 16))
    g = C.cosine_gram(x)
    assert float(C.geo_alignment_loss(x, g)) < 1e-6


def test_geo_loss_positive_off_consensus():
    x = _rand(9, (8, 16))
    gbar = C.cosine_gram(_rand(10, (8, 16)))
    assert float(C.geo_alignment_loss(x, gbar)) > 0.0


def test_geo_loss_differentiable():
    x = _rand(11, (8, 16))
    gbar = C.cosine_gram(_rand(12, (8, 16)))
    grad = jax.grad(lambda z: C.geo_alignment_loss(z, gbar))(x)
    assert jnp.isfinite(grad).all() and float(jnp.abs(grad).max()) > 0


def test_consensus_and_pairwise():
    grams = jnp.stack([C.cosine_gram(_rand(i, (6, 4))) for i in range(3)])
    gbar = C.consensus_gram(grams)
    np.testing.assert_allclose(np.asarray(gbar),
                               np.asarray(grams).mean(0), atol=1e-6)
    pc = C.pairwise_cka(grams)
    assert pc.shape == (3, 3)
    np.testing.assert_allclose(np.diag(pc), 1.0, atol=1e-5)


def test_centered_variant_runs():
    gx = C.cosine_gram(_rand(13, (10, 5)))
    v = float(C.cka(gx, gx, center=True))
    assert abs(v - 1.0) < 1e-5
