"""Sharding rule engine: tp/fsdp/dp layouts, divisibility fallbacks,
cache specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.launch import shardings as shd
from repro.launch.mesh import make_abstract_mesh
from repro.models import transformer as T


@pytest.fixture(scope="module")
def mesh16():
    # abstract rule checks only need mesh SHAPE; build a 1x1 real mesh is
    # not enough for divisibility, so use AbstractMesh (via the
    # version-compat constructor — the signature changed across jax releases)
    return make_abstract_mesh((4, 4), ("data", "model"))


def _params(arch):
    cfg = get_config(arch)
    return jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def test_tp_rules_dense(mesh16):
    p = _params("yi-6b")
    specs = shd.param_specs(p, mesh16)
    assert specs["embed"] == P("model", None)            # vocab 64000 % 4
    assert specs["blocks"]["attn"]["wq"]["w"] == P(None, None, "model")
    assert specs["blocks"]["attn"]["wo"]["w"] == P(None, "model", None)
    assert specs["blocks"]["mlp"]["down"]["w"] == P(None, "model", None)
    assert specs["final_norm"]["scale"] == P()


def test_tp_rules_moe_experts(mesh16):
    p = _params("deepseek-v2-236b")
    specs = shd.param_specs(p, mesh16)
    # experts (L, E, d, f): E over model, widest over data
    e = specs["blocks"]["moe"]["experts"]["gate"]["w"]
    assert e[1] == "model"
    assert specs["blocks"]["moe"]["router"]["w"] == P()


def test_fsdp_layout_contraction_dim(mesh16):
    p = _params("yi-6b")
    specs = shd.param_specs(p, mesh16, layout="fsdp")
    # linears shard dim -2 over both axes
    assert specs["blocks"]["mlp"]["up"]["w"] == \
        P(None, ("data", "model"), None)
    assert specs["embed"] == P(("data", "model"), None)


def test_dp_layout_replicates(mesh16):
    p = _params("smollm-135m")
    specs = shd.param_specs(p, mesh16, layout="dp")
    assert all(s == P() for s in jax.tree.leaves(specs)
               if isinstance(s, P))


def test_divisibility_fallback_logged(mesh16):
    shd.reset_explain()
    # 7 is not divisible by 4: replicate + log
    leaf = jax.ShapeDtypeStruct((10, 7), jnp.float32)
    spec = shd._leaf_spec(("blocks", "attn", "wq", "w"), leaf, mesh16)
    assert spec == P()
    assert any("col 7 % model" in m for m in shd.explain())


def test_batch_dim_spec(mesh16):
    assert shd.batch_dim_spec(mesh16, 8) == ("data",)    # 8 % 16 != 0 -> data
    assert shd.batch_dim_spec(mesh16, 16) == ("data",)   # no pod axis ->
    assert shd.batch_dim_spec(mesh16, 1) is None
    assert shd.batch_dim_spec(mesh16, 16, data_axes=("data", "model")) == \
        ("data", "model")


def test_cache_specs_structure(mesh16):
    cfg = reduced(get_config("recurrentgemma-9b"))
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 4, 64))
    specs = shd.cache_specs(cache, mesh16)
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, cache)) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs))
    assert specs["len"] == P()


def test_cache_specs_mla(mesh16):
    cfg = reduced(get_config("deepseek-v2-236b"))
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 4, 64))
    specs = shd.cache_specs(cache, mesh16)
    assert specs["c_kv"][1] in ("data", ("data",))   # batch dim sharded
