"""System-level behaviour: the sharded step functions on a local mesh, the
sharding rule engine, and the mesh-scale federated driver entrypoint."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, reduced
from repro.core import lora as lora_mod
from repro.launch import input_specs as ispec
from repro.launch import shardings as shd
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamW

KEY = jax.random.PRNGKey(0)


def test_fed_train_step_local_mesh():
    """The exact program the dry-run lowers, executed for real on the
    1-device mesh: federated FedSGD round with CKA + LAP weighting."""
    cfg = reduced(get_config("smollm-135m"))
    mesh = make_local_mesh()
    rt = T.Runtime(mesh=mesh, batch_axes=("data",), remat=True)
    params = T.init_params(KEY, cfg)
    params = lora_mod.attach_lora(KEY, params,
                                  lora_mod.LoRASpec(rank=4, dora=True))
    mask = lora_mod.trainable_mask(params)
    trainable, frozen = lora_mod.partition(params, mask)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(trainable)
    k_nodes = 2
    b, s, ba, la = 4, 32, 8, 16
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "anchors": jax.random.randint(KEY, (k_nodes, ba, la), 0,
                                      cfg.vocab_size),
    }
    gbar = jnp.eye(ba)
    step = steps_mod.make_fed_train_step(cfg, rt, opt, k_nodes=k_nodes)
    with mesh:
        new_tr, new_opt, gbar2, metrics = jax.jit(step)(
            trainable, frozen, opt_state, batch, gbar)
    assert bool(jnp.isfinite(metrics["task"]))
    assert bool(jnp.isfinite(metrics["geo"]))
    assert gbar2.shape == (ba, ba)
    # side-cars actually moved
    moved = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(new_tr),
                                jax.tree.leaves(trainable)))
    assert moved > 0


def test_moe_fed_train_step_local_mesh():
    cfg = reduced(get_config("llama4-scout-17b-a16e"))
    mesh = make_local_mesh()
    rt = T.Runtime(mesh=mesh, batch_axes=("data",), ep_axis="model")
    params = T.init_params(KEY, cfg)
    params = lora_mod.attach_lora(KEY, params, lora_mod.LoRASpec(rank=4))
    mask = lora_mod.trainable_mask(params)
    trainable, frozen = lora_mod.partition(params, mask)
    opt = AdamW(lr=1e-3)
    step = steps_mod.make_fed_train_step(cfg, rt, opt, k_nodes=2)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
        "anchors": jax.random.randint(KEY, (2, 6, 8), 0, cfg.vocab_size),
    }
    with mesh:
        _, _, _, metrics = jax.jit(step)(trainable, frozen,
                                         opt.init(trainable), batch,
                                         jnp.eye(6))
    assert bool(jnp.isfinite(metrics["task"]))


def test_decode_step_local_mesh():
    cfg = reduced(get_config("qwen3-32b"))
    mesh = make_local_mesh()
    rt = T.Runtime(mesh=mesh, batch_axes=("data",))
    params = T.init_params(KEY, cfg)
    cache = T.init_cache(cfg, 2, 64, rt)
    step = steps_mod.make_decode_step(cfg, rt)
    with mesh:
        logits, cache = jax.jit(step)(
            params, cache, {"tokens": jnp.zeros((2, 1), jnp.int32)})
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert int(cache["len"]) == 1


def test_sharding_rules_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("smollm-135m"))
    params = jax.eval_shape(lambda: T.init_params(KEY, cfg))
    shd.reset_explain()
    specs = shd.param_specs(params, mesh)
    # 1-way mesh: every rule falls back to replication, no crash
    assert all(isinstance(s, P) for s in jax.tree.leaves(specs)
               if isinstance(s, P))


def test_batch_spec_indivisible_batch_replicates():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert shd.batch_dim_spec(mesh, 1) is None


def test_input_specs_cover_all_shapes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("mistral-nemo-12b", "whisper-large-v3",
                 "phi-3-vision-4.2b"):
        cfg = get_config(arch)
        for name, shape in INPUT_SHAPES.items():
            if ispec.skip_reason(cfg, shape):
                continue
            if shape.kind == "train":
                batch, specs, gbar = ispec.train_batch_specs(cfg, shape, mesh)
                assert "anchors" in batch
            else:
                batch, specs = ispec.serve_batch_specs(cfg, shape, mesh)
            assert jax.tree.structure(batch) == jax.tree.structure(specs)


def test_whisper_skips_long_500k():
    cfg = get_config("whisper-large-v3")
    assert ispec.skip_reason(cfg, INPUT_SHAPES["long_500k"]) is not None
    assert ispec.skip_reason(cfg, INPUT_SHAPES["decode_32k"]) is None


def test_train_driver_entrypoint():
    from repro.launch.train import main
    final = main(["--tiny", "--rounds", "1", "--local-steps", "1",
                  "--batch", "2", "--seq", "32", "--anchors", "6",
                  "--nodes", "2"])
    assert final == final  # finite, no crash
