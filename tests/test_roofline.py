"""Roofline analysis: structural HLO collective parser + model FLOPs."""
import textwrap

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline import analysis as A

HLO = textwrap.dedent("""\
    HloModule jit_step, num_partitions=4

    %add.1 (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %r = f32[] add(%a, %b)
    }

    %cond.1 (t: (s32[], f32[8,16])) -> pred[] {
      %t = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%t), index=0
      %n = s32[] constant(10)
      ROOT %cmp = pred[] compare(%i, %n), direction=LT
    }

    %body.1 (t: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %t = (s32[], f32[8,16]) parameter(0)
      %x = f32[8,16]{1,0} get-tuple-element(%t), index=1
      %ar = f32[8,16]{1,0} all-reduce(%x), to_apply=%add.1
      %ag = f32[32,16]{1,0} all-gather(%x), dimensions={0}
      ROOT %out = (s32[], f32[8,16]) tuple(%t)
    }

    ENTRY %main.1 (p0: f32[8,16]) -> f32[8,16] {
      %p0 = f32[8,16]{1,0} parameter(0)
      %w = (s32[], f32[8,16]) while(%p0), condition=%cond.1, body=%body.1
      %top = f32[4,4]{1,0} all-reduce(%p0), to_apply=%add.1
      ROOT %r = f32[8,16]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_parser_counts_and_trip_multiplication():
    out = A.parse_collectives(HLO)
    # body all-reduce: 8*16*4 bytes * 2 (wire) * 10 trips = 10240
    # entry all-reduce: 4*4*4 * 2 = 128
    assert out["all-reduce"] == 8 * 16 * 4 * 2 * 10 + 4 * 4 * 4 * 2
    # all-gather result 32*16*4 * 1 (wire) * 10 trips
    assert out["all-gather"] == 32 * 16 * 4 * 10
    assert out["_counts"]["all-reduce"] == 2
    assert out["_counts"]["all-gather"] == 1


def test_parser_tuple_results():
    txt = HLO.replace(
        "%ar = f32[8,16]{1,0} all-reduce(%x), to_apply=%add.1",
        "%ar = (f32[8,16]{1,0}, bf16[4]{0}) all-reduce(%x, %x), "
        "to_apply=%add.1")
    out = A.parse_collectives(txt)
    per = (8 * 16 * 4 + 4 * 2) * 2
    assert out["all-reduce"] == per * 10 + 4 * 4 * 4 * 2


def test_parser_ignores_done_ops():
    txt = HLO.replace("%ar = f32[8,16]{1,0} all-reduce(%x), to_apply=%add.1",
                      "%ar = f32[8,16]{1,0} all-reduce-done(%x)")
    out = A.parse_collectives(txt)
    assert out["_counts"]["all-reduce"] == 1      # only the entry one


def test_shape_bytes_dtypes():
    assert A._shape_bytes("bf16", "2,3") == 12
    assert A._shape_bytes("f32", "5") == 20
    assert A._shape_bytes("pred", "8") == 8
    assert A._shape_bytes("s32", "") == 4         # scalar


def test_model_flops_dense_vs_moe():
    dense = get_config("yi-6b")
    moe = get_config("deepseek-v2-236b")
    tr = INPUT_SHAPES["train_4k"]
    d = tr.global_batch * tr.seq_len
    assert A.model_flops(dense, tr, training=True) == 6.0 * dense.param_count * d
    # MoE uses ACTIVE params
    got = A.model_flops(moe, tr, training=True)
    assert got == 6.0 * moe.active_param_count * d
    assert got < 6.0 * moe.param_count * d / 5


def test_model_flops_decode():
    cfg = get_config("yi-6b")
    dec = INPUT_SHAPES["decode_32k"]
    assert A.model_flops(cfg, dec, training=False) == \
        2.0 * cfg.param_count * dec.global_batch
