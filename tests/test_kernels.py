"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps per the brief."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gram import cosine_gram_pallas
from repro.kernels.lora_matmul import lora_matmul_pallas
from repro.kernels.selective_scan import selective_scan_pallas

KEY = jax.random.PRNGKey(0)


def rnd(i, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.fold_in(KEY, i), shape)
    return x.astype(dtype)


@pytest.mark.parametrize("b,d", [(8, 16), (32, 128), (50, 130), (128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel(b, d, dtype):
    x = rnd(1, (b, d), dtype)
    got = cosine_gram_pallas(x, block=32, interpret=True)
    want = ref.cosine_gram_ref(x)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


@pytest.mark.parametrize("b,d", [(8, 32), (33, 96)])
def test_gram_kernel_matches_core_cka(b, d):
    """The engine's server-side Gram dispatch target: the Pallas kernel in
    interpret mode must match ``core.cka.cosine_gram`` (the reference the
    engine uses off-TPU) to float32 tolerance."""
    from repro.core.cka import cosine_gram
    x = rnd(17, (b, d))
    got = cosine_gram_pallas(x, block=32, interpret=True)
    want = cosine_gram(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_engine_gram_backend_dispatch():
    """RoundEngine's ``gram_backend='pallas'`` path (interpret mode on CPU)
    must agree with the reference backend through a full engine round."""
    from repro.core.engine import EngineConfig, RoundEngine
    k, ba, dm = 3, 8, 16
    pooled_a = rnd(18, (k, ba, dm))
    ref_eng = RoundEngine(
        EngineConfig(n_nodes=k, local_steps=1, gram_backend="reference"),
        None, lambda *a: None, ({},))
    pal_eng = RoundEngine(
        EngineConfig(n_nodes=k, local_steps=1, gram_backend="pallas"),
        None, lambda *a: None, ({},))
    np.testing.assert_allclose(np.asarray(pal_eng._grams_of(pooled_a)),
                               np.asarray(ref_eng._grams_of(pooled_a)),
                               atol=1e-5)


@pytest.mark.parametrize("m,k,n,r", [(16, 32, 24, 4), (70, 100, 90, 8),
                                     (128, 256, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_kernel(m, k, n, r, dtype):
    x, w = rnd(2, (m, k), dtype), rnd(3, (k, n), dtype)
    a, b = rnd(4, (k, r), dtype), rnd(5, (r, n), dtype)
    got = lora_matmul_pallas(x, w, a, b, scale=0.7, bm=32, bn=32, bk=64,
                             interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, 0.7)
    scale = float(jnp.abs(want.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(got.astype(jnp.float32)
                        - want.astype(jnp.float32)).max()) / scale
    assert err < (1e-5 if dtype == jnp.float32 else 3e-2)


@pytest.mark.parametrize("bh,sq,dh,n_rep", [(4, 64, 32, 1), (8, 100, 32, 2),
                                            (6, 128, 64, 3)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel(bh, sq, dh, n_rep, causal):
    q = rnd(6, (bh, sq, dh))
    k = rnd(7, (bh // n_rep, sq, dh))
    v = rnd(8, (bh // n_rep, sq, dh))
    got = flash_attention_pallas(q, k, v, causal=causal, n_rep=n_rep,
                                 bq=32, bkv=32, interpret=True)
    want = ref.flash_attention_ref(q, jnp.repeat(k, n_rep, 0),
                                   jnp.repeat(v, n_rep, 0), causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    q = rnd(9, (4, 64, 32), jnp.bfloat16)
    k = rnd(10, (4, 64, 32), jnp.bfloat16)
    v = rnd(11, (4, 64, 32), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, bq=32, bkv=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


# ----------------------------------------------------------------------
# decode attention: single-token queries against the packed KV pool
SENTINEL = jnp.iinfo(jnp.int32).max // 2


def _pool(i, s_slots, c, n_kv, rep, dh, lens, window=0, dtype=jnp.float32):
    """Build a serving-style pool: slot j holds lens[j] tokens, laid out as
    a ring of width c when window > 0 (entry for position p at slot p % c),
    linear otherwise; empty entries carry the position sentinel."""
    h = n_kv * rep
    q = rnd(100 + i, (s_slots, h, dh), dtype)
    k = rnd(101 + i, (s_slots, c, n_kv, dh), dtype)
    v = rnd(102 + i, (s_slots, c, n_kv, dh), dtype)
    lens = jnp.asarray(lens, jnp.int32)
    slots = jnp.arange(c, dtype=jnp.int32)[None, :]
    if window:
        # ring layout: slot j holds positions p with p % c == slot index
        # and lens[j] - c <= p < lens[j]
        wrap = ((lens[:, None] - 1 - slots) // c) * c + slots
        pos = jnp.where(wrap >= 0, wrap, SENTINEL)
        pos = jnp.where(slots < jnp.minimum(lens[:, None], c), pos, SENTINEL)
        pos = jnp.where(wrap < lens[:, None], pos, SENTINEL)
    else:
        pos = jnp.where(slots < lens[:, None], slots, SENTINEL)
    return q, k, v, lens, pos


@pytest.mark.parametrize("n_kv,rep", [(2, 1), (2, 4), (3, 2)])
def test_decode_attention_gqa_grouping(n_kv, rep):
    """GQA head grouping: query head h must read KV head h // rep."""
    s_slots, c, dh = 3, 40, 32
    q, k, v, lens, pos = _pool(0, s_slots, c, n_kv, rep, dh, [40, 17, 1])
    got = decode_attention_pallas(q, k, v, lens, pos, bkv=16, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_decode_attention_ring_window():
    """Ring-buffer SWA: positions wrap mod C and only the last ``window``
    are visible; wrapped and unwrapped slots must agree with the oracle."""
    s_slots, c, n_kv, rep, dh, w = 4, 24, 2, 2, 32, 24
    # lens: partially filled, exactly full, wrapped once, wrapped many times
    q, k, v, lens, pos = _pool(7, s_slots, c, n_kv, rep, dh,
                               [9, 24, 31, 100], window=w)
    got = decode_attention_pallas(q, k, v, lens, pos, window=w, bkv=8,
                                  interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, pos, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_decode_attention_padded_slots():
    """Partially-filled slots: entries beyond each slot's length carry the
    position sentinel and must get exactly zero attention weight."""
    s_slots, c, n_kv, rep, dh = 3, 50, 2, 2, 32
    q, k, v, lens, pos = _pool(13, s_slots, c, n_kv, rep, dh, [1, 13, 50])
    # poison the invalid tail: if masking leaks, the output moves
    bad = jnp.where((pos == SENTINEL)[..., None, None], 1e4, 1.0)
    got = decode_attention_pallas(q, k * bad, v * bad, lens, pos, bkv=16,
                                  interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_decode_attention_matches_blockwise_oracle():
    """The kernel must agree with the model's decode path oracle
    (attention.blockwise_attention with per-slot positions)."""
    from repro.models.attention import blockwise_attention
    # q_pos <= C-1, as in the engine: a linear buffer always has room for
    # the current token, so the un-windowed bound (q_pos - kv_pos < C)
    # never masks a live entry
    s_slots, c, n_kv, rep, dh = 2, 33, 2, 3, 32
    q, k, v, lens, pos = _pool(21, s_slots, c, n_kv, rep, dh, [20, 32])
    got = decode_attention_pallas(q, k, v, lens, pos, bkv=16, interpret=True)
    want = blockwise_attention(q[:, None].reshape(s_slots, 1, n_kv * rep, dh),
                               k, v, kind="causal", window=c,
                               q_positions=lens[:, None], kv_positions=pos)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want[:, 0]), atol=1e-5)


def test_decode_attention_bf16():
    s_slots, c, n_kv, rep, dh = 2, 32, 2, 2, 32
    q, k, v, lens, pos = _pool(29, s_slots, c, n_kv, rep, dh, [32, 11],
                               dtype=jnp.bfloat16)
    got = decode_attention_pallas(q, k, v, lens, pos, bkv=16, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


@pytest.mark.parametrize("b,s,c,chunk", [(2, 37, 45, 16), (1, 64, 32, 32),
                                         (3, 128, 17, 16)])
def test_selective_scan_kernel(b, s, c, chunk):
    da = jax.random.uniform(jax.random.fold_in(KEY, 12), (b, s, c),
                            minval=0.3, maxval=0.99)
    dbx = rnd(13, (b, s, c))
    h0 = rnd(14, (b, c))
    h, hl = selective_scan_pallas(da, dbx, h0, chunk=chunk, bc=16,
                                  interpret=True)
    hr, hlr = ref.selective_scan_ref(da, dbx, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr), rtol=2e-4,
                               atol=1e-5)


def test_selective_scan_matches_model_scan():
    """Kernel agrees with the chunked associative scan used in the model."""
    from repro.models.ssm import _chunked_diag_scan
    da = jax.random.uniform(jax.random.fold_in(KEY, 15), (2, 32, 8),
                            minval=0.5, maxval=0.99)
    dbx = rnd(16, (2, 32, 8))
    h0 = jnp.zeros((2, 8))
    h1, hl1 = _chunked_diag_scan(da, dbx, h0, 8)
    h2, hl2 = ref.selective_scan_ref(da, dbx, h0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-5)
