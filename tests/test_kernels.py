"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps per the brief."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gram import cosine_gram_pallas
from repro.kernels.lora_matmul import lora_matmul_pallas
from repro.kernels.selective_scan import selective_scan_pallas

KEY = jax.random.PRNGKey(0)


def rnd(i, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.fold_in(KEY, i), shape)
    return x.astype(dtype)


@pytest.mark.parametrize("b,d", [(8, 16), (32, 128), (50, 130), (128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel(b, d, dtype):
    x = rnd(1, (b, d), dtype)
    got = cosine_gram_pallas(x, block=32, interpret=True)
    want = ref.cosine_gram_ref(x)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


@pytest.mark.parametrize("b,d", [(8, 32), (33, 96)])
def test_gram_kernel_matches_core_cka(b, d):
    """The engine's server-side Gram dispatch target: the Pallas kernel in
    interpret mode must match ``core.cka.cosine_gram`` (the reference the
    engine uses off-TPU) to float32 tolerance."""
    from repro.core.cka import cosine_gram
    x = rnd(17, (b, d))
    got = cosine_gram_pallas(x, block=32, interpret=True)
    want = cosine_gram(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_engine_gram_backend_dispatch():
    """RoundEngine's ``gram_backend='pallas'`` path (interpret mode on CPU)
    must agree with the reference backend through a full engine round."""
    from repro.core.engine import EngineConfig, RoundEngine
    k, ba, dm = 3, 8, 16
    pooled_a = rnd(18, (k, ba, dm))
    ref_eng = RoundEngine(
        EngineConfig(n_nodes=k, local_steps=1, gram_backend="reference"),
        None, lambda *a: None, ({},))
    pal_eng = RoundEngine(
        EngineConfig(n_nodes=k, local_steps=1, gram_backend="pallas"),
        None, lambda *a: None, ({},))
    np.testing.assert_allclose(np.asarray(pal_eng._grams_of(pooled_a)),
                               np.asarray(ref_eng._grams_of(pooled_a)),
                               atol=1e-5)


@pytest.mark.parametrize("m,k,n,r", [(16, 32, 24, 4), (70, 100, 90, 8),
                                     (128, 256, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_kernel(m, k, n, r, dtype):
    x, w = rnd(2, (m, k), dtype), rnd(3, (k, n), dtype)
    a, b = rnd(4, (k, r), dtype), rnd(5, (r, n), dtype)
    got = lora_matmul_pallas(x, w, a, b, scale=0.7, bm=32, bn=32, bk=64,
                             interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, 0.7)
    scale = float(jnp.abs(want.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(got.astype(jnp.float32)
                        - want.astype(jnp.float32)).max()) / scale
    assert err < (1e-5 if dtype == jnp.float32 else 3e-2)


@pytest.mark.parametrize("bh,sq,dh,n_rep", [(4, 64, 32, 1), (8, 100, 32, 2),
                                            (6, 128, 64, 3)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel(bh, sq, dh, n_rep, causal):
    q = rnd(6, (bh, sq, dh))
    k = rnd(7, (bh // n_rep, sq, dh))
    v = rnd(8, (bh // n_rep, sq, dh))
    got = flash_attention_pallas(q, k, v, causal=causal, n_rep=n_rep,
                                 bq=32, bkv=32, interpret=True)
    want = ref.flash_attention_ref(q, jnp.repeat(k, n_rep, 0),
                                   jnp.repeat(v, n_rep, 0), causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    q = rnd(9, (4, 64, 32), jnp.bfloat16)
    k = rnd(10, (4, 64, 32), jnp.bfloat16)
    v = rnd(11, (4, 64, 32), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, bq=32, bkv=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


@pytest.mark.parametrize("b,s,c,chunk", [(2, 37, 45, 16), (1, 64, 32, 32),
                                         (3, 128, 17, 16)])
def test_selective_scan_kernel(b, s, c, chunk):
    da = jax.random.uniform(jax.random.fold_in(KEY, 12), (b, s, c),
                            minval=0.3, maxval=0.99)
    dbx = rnd(13, (b, s, c))
    h0 = rnd(14, (b, c))
    h, hl = selective_scan_pallas(da, dbx, h0, chunk=chunk, bc=16,
                                  interpret=True)
    hr, hlr = ref.selective_scan_ref(da, dbx, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr), rtol=2e-4,
                               atol=1e-5)


def test_selective_scan_matches_model_scan():
    """Kernel agrees with the chunked associative scan used in the model."""
    from repro.models.ssm import _chunked_diag_scan
    da = jax.random.uniform(jax.random.fold_in(KEY, 15), (2, 32, 8),
                            minval=0.5, maxval=0.99)
    dbx = rnd(16, (2, 32, 8))
    h0 = jnp.zeros((2, 8))
    h1, hl1 = _chunked_diag_scan(da, dbx, h0, 8)
    h2, hl2 = ref.selective_scan_ref(da, dbx, h0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-5)
