"""End-to-end federation integration tests (the paper's protocol)."""
import jax
import pytest

from repro.configs import get_config
from repro.core.federation import Federation, FederationConfig

TINY = get_config("fedmm-small").with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32")


def _run(method="geolora", aggregation="precision", rounds=2, corrupt=()):
    fed = FederationConfig(n_nodes=4, rounds=rounds, local_steps=4,
                           local_batch=16, method=method,
                           aggregation=aggregation, corrupt_nodes=corrupt,
                           lora_rank=4)
    f = Federation(fed, TINY)
    f.run()
    return f


@pytest.fixture(scope="module")
def geolora_run():
    return _run("geolora")


def test_task_loss_decreases(geolora_run):
    h = geolora_run.history
    assert h[-1]["task_loss"] < h[0]["task_loss"]


def test_cross_modality_alignment_improves(geolora_run):
    """The paper's central claim: CKA-regularised rounds pull the disjoint
    modality geometries together."""
    h = geolora_run.history
    assert h[-1]["cross_node_cka"] > 0.8
    assert h[-1]["geo_loss"] < h[0]["geo_loss"] + 1e-6


def test_communication_is_low_rank_sized(geolora_run):
    h = geolora_run.history[-1]
    assert h["uplink_bytes"] < 0.05 * h["full_model_bytes"]


def test_geodora_runs_and_aligns():
    f = _run("geodora", rounds=2)
    h = f.history
    assert h[-1]["cross_node_cka"] > 0.75
    # DoRA magnitudes exist and stay finite
    import jax.numpy as jnp
    lb = [l for l in jax.tree.leaves(f.nodes[0]["trainable"])
          if l is not None]
    assert all(bool(jnp.isfinite(x).all()) for x in lb)


def test_precision_weighting_downweights_corrupt_node():
    """LAP uncertainty must detect the node whose data is latent-free noise
    (the paper's argument for synthetic-anchor robustness)."""
    f = _run("geolora", aggregation="precision", rounds=2, corrupt=(2,))
    w = f.history[-1]["weights"]
    others = [w[i] for i in range(4) if i != 2]
    assert w[2] < min(others), f"corrupt node not downweighted: {w}"


def test_uniform_vs_precision_differ():
    fu = _run("geolora", aggregation="uniform", rounds=1, corrupt=(1,))
    assert max(fu.history[-1]["weights"]) - min(fu.history[-1]["weights"]) \
        < 1e-6


def test_bridge_client_hybrid_federation():
    """Paper's hybrid federation: a node with locally PAIRED data adds an
    intra-node contrastive loss (bridge client) and the federation still
    converges and aligns."""
    fed = FederationConfig(n_nodes=4, rounds=2, local_steps=4,
                           local_batch=16, method="geolora",
                           bridge_nodes=(0,), lambda_bridge=0.5)
    f = Federation(fed, TINY)
    h = f.run()
    assert "adapter2" in f.nodes[0]["trainable"]
    assert "adapter2" not in f.nodes[1]["trainable"]
    assert h[-1]["cross_node_cka"] > 0.8
    assert h[-1]["task_loss"] < h[0]["task_loss"] + 0.5


def test_synthetic_anchors_downweighted():
    """Paper: 'precision-weighted aggregation naturally detects the
    distributional shift between real private data and synthetic anchors,
    assigning higher uncertainty to these nodes'."""
    fed = FederationConfig(n_nodes=4, rounds=2, local_steps=5,
                           local_batch=16, method="geolora",
                           aggregation="precision",
                           synthetic_anchor_nodes=(1,))
    f = Federation(fed, TINY)
    h = f.run()
    w = h[-1]["weights"]
    assert w[1] < min(w[i] for i in (0, 2, 3)), w


def test_federation_checkpoint_resume(tmp_path):
    """Server checkpoint: save after round 1, resume in a fresh federation,
    next round is bit-identical to the uninterrupted run."""
    import os
    import jax
    import numpy as np

    def make():
        return Federation(FederationConfig(
            n_nodes=2, rounds=2, local_steps=2, local_batch=8,
            method="geolora", aggregation="uniform"), TINY)

    f1 = make()
    f1.run_round()
    path = os.path.join(tmp_path, "fed.npz")
    f1.save(path)
    r_cont = f1.run_round()

    f2 = make()
    step = f2.restore(path)
    assert step == 1
    r_resumed = f2.run_round()
    assert abs(r_cont["task_loss"] - r_resumed["task_loss"]) < 1e-5
    assert abs(r_cont["cross_node_cka"] - r_resumed["cross_node_cka"]) < 1e-5
    for a, b in zip(jax.tree.leaves(f1.nodes[0]["trainable"]),
                    jax.tree.leaves(f2.nodes[0]["trainable"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
