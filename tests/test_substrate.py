"""Substrate tests: optimizer, checkpoint, data pipeline, tokenizers."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticLMStream
from repro.data.synthetic import SyntheticMultimodal
from repro.data.tokenizers import FrozenTokenizer
from repro.optim.adamw import AdamW, warmup_cosine

KEY = jax.random.PRNGKey(0)


def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adamw_skips_none_leaves():
    opt = AdamW(lr=0.1)
    params = {"a": jnp.ones(3), "b": None}
    state = opt.init(params)
    grads = {"a": jnp.ones(3), "b": None}
    new, _ = opt.update(grads, state, params)
    assert new["b"] is None
    assert float(new["a"][0]) < 1.0


def test_grad_clip():
    opt = AdamW(lr=0.0, grad_clip=1.0)   # lr 0: check state only, no nan
    params = {"x": jnp.ones(4)}
    state = opt.init(params)
    new, st = opt.update({"x": 1e9 * jnp.ones(4)}, state, params)
    assert bool(jnp.isfinite(st["m"]["x"]).all())


def test_warmup_cosine_shape():
    s = warmup_cosine(10, 100)
    assert float(s(jnp.asarray(0))) < 0.11
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(s(jnp.asarray(100))) <= 0.11


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.zeros((), jnp.int32)},
            "e": [jnp.ones(2), jnp.zeros(3)]}
    p = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(p, tree, step=7)
    back, step = load_checkpoint(p, tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_structure_mismatch(tmp_path):
    p = os.path.join(tmp_path, "c.npz")
    save_checkpoint(p, {"a": jnp.ones(3)})
    import pytest
    with pytest.raises(ValueError):
        load_checkpoint(p, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_lm_stream_deterministic_and_learnable():
    s1 = list(zip(range(2), SyntheticLMStream(64, 16, 4, seed=3)))
    s2 = list(zip(range(2), SyntheticLMStream(64, 16, 4, seed=3)))
    for (_, a), (_, b) in zip(s1, s2):
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
    b = s1[0][1]
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_tokenizer_frozen_deterministic():
    tok = FrozenTokenizer("image", d_raw=32, n_tokens=8, d_out=64)
    x = jax.random.normal(KEY, (5, 32))
    np.testing.assert_array_equal(np.asarray(tok(x)), np.asarray(tok(x)))
    assert tok(x).shape == (5, 8, 64)


def test_synthetic_modalities_share_latent_geometry():
    """Same-class samples across modalities must be alignable (the data
    property the paper's anchors exploit): within-class latent distances
    are smaller than across-class, in every modality."""
    task = SyntheticMultimodal(n_classes=4, seed=1)
    for m in ("image", "text"):
        raw, labels = task.sample(KEY, m, 256)
        raw = np.asarray(raw)
        labels = np.asarray(labels)
        centroids = np.stack([raw[labels == c].mean(0) for c in range(4)])
        within = np.mean([np.linalg.norm(raw[labels == c]
                                         - centroids[c], axis=1).mean()
                          for c in range(4)])
        across = np.mean([np.linalg.norm(centroids[c] - centroids[d])
                          for c in range(4) for d in range(4) if c != d])
        assert across > 1.5 * within


def test_corrupt_node_has_no_structure():
    """Corrupt nodes show no class separation: between-centroid distance is
    not materially larger than within-class spread (ratio ~= sampling
    noise), unlike structured nodes where it exceeds 1.5x."""
    task = SyntheticMultimodal(n_classes=4, seed=1)
    raw, labels = task.sample(KEY, "image", 256, corrupt=True)
    raw, labels = np.asarray(raw), np.asarray(labels)
    centroids = np.stack([raw[labels == c].mean(0) for c in range(4)])
    within = np.mean([np.linalg.norm(raw[labels == c] - centroids[c],
                                     axis=1).mean() for c in range(4)])
    across = np.mean([np.linalg.norm(centroids[c] - centroids[d])
                      for c in range(4) for d in range(4) if c != d])
    assert across < 0.5 * within


def test_anchor_set_unpaired_but_classwise():
    task = SyntheticMultimodal(n_classes=4, seed=2)
    anchors = task.anchor_set(KEY, n_per_class=3)
    assert set(anchors) == set(task.modalities)
    for m, (raw, labels) in anchors.items():
        assert raw.shape[0] == 12
        np.testing.assert_array_equal(np.asarray(labels),
                                      np.repeat(np.arange(4), 3))
