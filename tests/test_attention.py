"""Attention module: blockwise online-softmax vs direct, masks, MLA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MLAConfig, get_config, reduced
from repro.models import attention as A

KEY = jax.random.PRNGKey(0)


def rnd(i, shape):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape)


def naive(q, k, v, kind, window):
    b, t, h, dh = q.shape
    s, kv = k.shape[1], k.shape[2]
    rep = h // kv
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    sc = jnp.einsum("bthd,bshd->bhts", q, kk) * dh ** -0.5
    i = jnp.arange(t)[:, None]
    j = jnp.arange(s)[None, :]
    if kind == "causal":
        ok = j <= i
    elif kind == "sliding":
        ok = (j <= i) & (i - j < window)
    elif kind == "chunked":
        ok = (j <= i) & (i // window == j // window)
    else:
        ok = jnp.ones((t, s), bool)
    sc = jnp.where(ok[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", p, vv)


@pytest.mark.parametrize("kind,window", [("causal", 0), ("sliding", 7),
                                         ("chunked", 16), ("full", 0)])
@pytest.mark.parametrize("kv_block", [8, 16, 64])
def test_blockwise_matches_naive(kind, window, kv_block):
    q = rnd(1, (2, 48, 4, 16))
    k = rnd(2, (2, 48, 2, 16))
    v = rnd(3, (2, 48, 2, 16))
    got = A.blockwise_attention(q, k, v, kind=kind, window=window,
                                kv_block=kv_block)
    want = naive(q, k, v, kind, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_rope_relative_shift_property():
    """RoPE scores depend on relative distance: shifting all positions by a
    constant leaves q.k dot products unchanged."""
    x = rnd(4, (1, 8, 2, 32))
    p0 = jnp.arange(8)[None]
    r1 = A.apply_rope(x, p0, 1e4)
    r2 = A.apply_rope(x, p0 + 100, 1e4)
    s1 = jnp.einsum("bthd,bshd->bhts", r1, r1)
    s2 = jnp.einsum("bthd,bshd->bhts", r2, r2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)


def test_gqa_decode_ring_buffer_sliding():
    """Decode with a ring buffer must equal full-context SWA forward."""
    cfg = reduced(get_config("mistral-nemo-12b"))
    p = A.make_gqa(KEY, cfg, jnp.float32)
    w = 8
    s_total = 20
    x = rnd(5, (1, s_total, cfg.d_model))
    full = A.gqa_forward(p, x, cfg, kind="sliding", window=w)
    cache = A.init_kv_cache(1, w, cfg.n_kv_heads, cfg.head_dim, jnp.float32)
    outs = []
    for t in range(s_total):
        o, cache = A.gqa_decode(p, x[:, t:t + 1], cache, cfg,
                                kind="sliding", window=w)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_mla_decode_matches_forward():
    """Absorbed compressed-cache decode == lazy-upproject forward."""
    cfg = get_config("deepseek-v2-236b")
    cfg = reduced(cfg)
    p = A.make_mla(KEY, cfg, jnp.float32)
    s = 12
    x = rnd(6, (2, s, cfg.d_model))
    full = A.mla_forward(p, x, cfg)
    cache = A.init_mla_cache(2, s + 2, cfg, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = A.mla_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_cross_attention_decode():
    cfg = reduced(get_config("whisper-large-v3"))
    p = A.make_gqa(KEY, cfg, jnp.float32)
    enc = rnd(7, (2, 10, cfg.d_model))
    x = rnd(8, (2, 1, cfg.d_model))
    cross = A.precompute_cross_kv(p, enc, cfg)
    o1 = A.gqa_cross_decode(p, x, cross, cfg)
    o2 = A.gqa_forward(p, x, cfg, x_cross=enc)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)
