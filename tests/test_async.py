"""Asynchronous staleness-aware federation (ISSUE 7): the on-device
fault simulator (lag, crash-and-rejoin, transient non-report, poison),
the buffered staleness-weighted server step, the quarantine guard, and
in-block crash recovery.

The bar: the compiled async engine matches the eager sequential oracle
running IDENTICAL lag/failure streams (control streams exactly, numerics
at the suite-standard sequential-vs-engine tolerance); a NaN-poisoned
node leaves the globals finite with its quarantine counter bumped every
round it reports; and a kill-and-resume from an in-block checkpoint tap
is bit-identical while losing < M rounds."""
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import participation as part_mod
from repro.core import uncertainty as unc
from repro.core.cka import consensus_gram
from repro.core.engine import auto_block_size
from repro.core.federation import (Federation, FederationConfig,
                                   ParticipationPlan, SequentialFederation)

TINY = get_config("fedmm-small").with_(
    n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    d_ff=64, vocab_size=128, dtype="float32")

BASE2 = dict(n_nodes=2, local_steps=1, local_batch=4,
             modalities=("genetics", "tabular"), bridge_modality="tabular",
             anchors_per_class=2, n_tokens=4, lora_rank=4)

BASE4 = dict(n_nodes=4, local_steps=2, local_batch=8,
             modalities=("genetics", "tabular"), bridge_modality="tabular",
             anchors_per_class=2, n_tokens=4, lora_rank=4)

ASYNC_FAULTY = ParticipationPlan(
    strategy="async", lag_dist="geometric", lag_p=0.5, max_lag=3,
    transient_rate=0.2, crash_rate=0.1, rejoin_rate=0.5, seed=3)


# ----------------------------------------------------------------------
# plan / schedule units
def test_async_plan_validation():
    with pytest.raises(ValueError):
        ParticipationPlan(strategy="async", lag_dist="bogus")
    with pytest.raises(ValueError):
        ParticipationPlan(strategy="async", staleness="bogus")
    with pytest.raises(ValueError):
        ParticipationPlan(strategy="async", lag=5, max_lag=3)
    with pytest.raises(ValueError):
        ParticipationPlan(strategy="async", crash_rate=1.0)
    with pytest.raises(ValueError):
        ParticipationPlan(strategy="async", quarantine_norm=0.0)
    p = ParticipationPlan(strategy="async", max_staleness=2)
    # async plans round-trip through checkpoint meta
    assert part_mod.plan_from_meta(part_mod.plan_meta(p)) == p


def test_staleness_factor_units():
    lag = jnp.array([0.0, 1.0, 3.0])
    poly = unc.staleness_factor(lag, schedule="poly", alpha=1.0)
    np.testing.assert_allclose(np.asarray(poly), [1.0, 0.5, 0.25])
    cut = unc.staleness_factor(lag, schedule="cutoff", max_staleness=1)
    np.testing.assert_allclose(np.asarray(cut), [1.0, 1.0, 0.0])
    # poly + bounded staleness composes: discount then hard-drop
    both = unc.staleness_factor(lag, schedule="poly", alpha=1.0,
                                max_staleness=1)
    np.testing.assert_allclose(np.asarray(both), [1.0, 0.5, 0.0])
    with pytest.raises(ValueError):
        unc.staleness_factor(lag, schedule="cutoff")   # needs max_staleness


def test_stale_precision_weights_normalise_and_zero():
    prec = jnp.array([1.0, 3.0, 2.0])
    w = unc.stale_precision_weights(prec, jnp.array([0.0, 1.0, 0.0]),
                                    jnp.array([1.0, 1.0, 0.0]))
    np.testing.assert_allclose(float(w.sum()), 1.0, rtol=1e-6)
    assert float(w[2]) == 0.0                      # masked out
    # node 1's lag halves its effective precision: its share drops below
    # the undiscounted precision share 3/(3+1)
    np.testing.assert_allclose(float(w[1]), 1.5 / 2.5, rtol=1e-6)
    assert float(w[1]) < 3.0 / 4.0
    # no deliveries -> all-zero weights, NOT NaN
    w0 = unc.stale_precision_weights(prec, jnp.zeros(3), jnp.zeros(3))
    np.testing.assert_array_equal(np.asarray(w0), np.zeros(3))


def test_consensus_gram_fallback():
    grams = jnp.stack([jnp.eye(3), 2.0 * jnp.eye(3)])
    prev = 7.0 * jnp.eye(3)
    got = consensus_gram(grams, mask=jnp.array([0.0, 1.0]), fallback=prev)
    np.testing.assert_allclose(np.asarray(got), 2.0 * np.eye(3))
    # empty mask keeps the previous consensus instead of the zero Gram
    kept = consensus_gram(grams, mask=jnp.zeros(2), fallback=prev)
    np.testing.assert_allclose(np.asarray(kept), 7.0 * np.eye(3))


# ----------------------------------------------------------------------
# degenerate inputs (ISSUE 7 satellite): allocator + auto block size
def test_allocate_cohort_degenerate_inputs():
    # empty bucket groups get 0 slots, non-empty ones still >= 1
    assert part_mod.allocate_cohort(2, (0, 2, 2)) == (0, 1, 1)
    assert part_mod.allocate_cohort(3, (4, 0)) == (3, 0)
    # C == number of non-empty buckets -> one slot each
    assert part_mod.allocate_cohort(2, (0, 3, 3)) == (0, 1, 1)
    with pytest.raises(ValueError):                # C > K total nodes
        part_mod.allocate_cohort(5, (0, 2, 2))
    with pytest.raises(ValueError):                # C < non-empty buckets
        part_mod.allocate_cohort(1, (0, 2, 2))


def test_auto_block_size_degenerate_inputs():
    # zero/negative measured round time -> cap (can't normalise)
    assert auto_block_size(0.01, 0.0) == 64
    assert auto_block_size(0.01, -1.0) == 64
    # zero measured dispatch overhead -> no fusion needed
    assert auto_block_size(0.0, 1.0) == 1
    assert auto_block_size(-0.5, 1.0) == 1
    # normal regime: smallest M with dispatch/M < 5% of round
    assert auto_block_size(0.5, 1.0) == 10
    # cap clamps absurd overhead ratios
    assert auto_block_size(100.0, 0.001) == 64


# ----------------------------------------------------------------------
# delivery-timing semantics via the eager oracle (cheap: no block jit)
def test_async_fixed_lag_delivery_timing():
    """Fixed lag L, no failures: a node starts a report, the report lands
    L rounds later, the node idles in between and restarts the round
    after delivery — starts at rounds 0, L+1, 2(L+1), ..."""
    plan = ParticipationPlan(strategy="async", lag_dist="fixed", lag=2,
                             seed=0)
    seq = SequentialFederation(FederationConfig(**BASE2), TINY)
    recs = seq.run_rounds(6, participation=plan)
    starts = [r["participation"][0] for r in recs]
    delivered = [r["delivered"][0] for r in recs]
    assert starts == [1.0, 0.0, 0.0, 1.0, 0.0, 0.0]
    assert delivered == [0.0, 0.0, 1.0, 0.0, 0.0, 1.0]
    # delivered reports carry their lag; undelivered rounds report -1
    assert [r["staleness"][0] for r in recs] == \
        [-1.0, -1.0, 2.0, -1.0, -1.0, 2.0]
    # lag 0 delivers the same round: synchronous timing
    plan0 = ParticipationPlan(strategy="async", lag_dist="fixed", lag=0,
                              seed=0)
    seq0 = SequentialFederation(FederationConfig(**BASE2), TINY)
    recs0 = seq0.run_rounds(3, participation=plan0)
    for r in recs0:
        assert r["participation"] == [1.0, 1.0]
        assert r["delivered"] == [1.0, 1.0]


# ----------------------------------------------------------------------
# compiled engine vs eager oracle under identical fault streams
def test_async_engine_matches_sequential_oracle():
    fed = FederationConfig(**BASE4)
    eng = Federation(fed, TINY)
    seq = SequentialFederation(fed, TINY)
    he = eng.run_rounds(4, participation=ASYNC_FAULTY)
    hs = seq.run_rounds(4, participation=ASYNC_FAULTY)
    for a, b in zip(he, hs):
        # control streams are EXACT: same on-device RNG, same event
        # algebra run compiled vs eagerly
        assert a["participation"] == b["participation"]
        assert a["delivered"] == b["delivered"]
        assert a["staleness"] == b["staleness"]
        assert a["quarantined"] == b["quarantined"]
        # numerics at the suite-standard engine-vs-sequential tolerance
        np.testing.assert_allclose(a["task_loss"], b["task_loss"],
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(a["weights"], b["weights"], atol=1e-4)
        np.testing.assert_allclose(a["cross_node_cka"], b["cross_node_cka"],
                                   rtol=1e-4, atol=1e-4)
    for i in range(fed.n_nodes):
        for x, y in zip(jax.tree.leaves(eng.node_params(i)),
                        jax.tree.leaves(seq.node_params(i))):
            if x is not None:
                np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                           rtol=1e-4, atol=1e-4)


def test_async_fused_blocks_match_per_round():
    """The async carry (ctl + report buffer) rides the fused-block scan:
    M-round blocks must reproduce the per-round path exactly."""
    plan = ParticipationPlan(strategy="async", lag_dist="fixed", lag=1,
                             crash_rate=0.2, rejoin_rate=0.5, seed=7)
    fed = FederationConfig(**BASE2)
    f1 = Federation(fed, TINY)
    f2 = Federation(fed, TINY)
    h1 = f1.run_rounds(4, participation=plan)
    h2 = f2.run_rounds(4, block_size=2, participation=plan)
    for a, b in zip(h1, h2):
        assert a["participation"] == b["participation"]
        assert a["delivered"] == b["delivered"]
        np.testing.assert_allclose(a["task_loss"], b["task_loss"],
                                   rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# quarantine guard: a NaN-poisoned node cannot touch the globals
def test_poisoned_node_quarantined_globals_finite():
    plan = ParticipationPlan(strategy="async", lag_dist="fixed", lag=0,
                             poison_nodes=(1,), seed=5)
    fed = FederationConfig(**BASE2)
    f = Federation(fed, TINY)
    recs = f.run_rounds(4, participation=plan)
    # always-online lag-0 poison node reports (and is quarantined) every
    # round; the healthy node never is
    assert recs[-1]["quarantined"] == [0.0, 4.0]
    assert all(r["weights"][1] == 0.0 for r in recs)
    assert np.isfinite(np.asarray(f.gbar)).all()
    for i in range(fed.n_nodes):
        for leaf in jax.tree.leaves(f.node_params(i)):
            if leaf is not None:
                assert np.isfinite(np.asarray(leaf)).all()
    # the run still makes progress: healthy deliveries happen
    assert sum(r["n_delivered"] for r in recs) >= 4


# ----------------------------------------------------------------------
# in-block checkpoint taps: preemption loses < M rounds
def test_inblock_checkpoint_kill_and_resume_bit_identical(tmp_path):
    """checkpoint_every=N < M streams state taps from INSIDE the compiled
    block; killing after round 2 of a 4-round run and restoring the
    in-block checkpoint replays rounds 3-4 bit-identically."""
    plan = ParticipationPlan(strategy="async", lag_dist="fixed", lag=1,
                             crash_rate=0.2, rejoin_rate=0.5, seed=7)
    fed = FederationConfig(**BASE2)
    ck = os.path.join(tmp_path, "ck_{step}.npz")
    f1 = Federation(fed, TINY)
    f1.run_rounds(4, block_size=2, participation=plan,
                  checkpoint_path=ck, checkpoint_every=2)
    assert sorted(os.listdir(tmp_path)) == ["ck_2.npz", "ck_4.npz"]

    f2 = Federation(fed, TINY)
    assert f2.restore(os.path.join(tmp_path, "ck_2.npz")) == 2
    f2.run_rounds(2, block_size=2, participation=plan)
    for x, y in zip(jax.tree.leaves((f1._trains, f1._opts, f1._keys,
                                     f1.gbar)),
                    jax.tree.leaves((f2._trains, f2._opts, f2._keys,
                                     f2.gbar))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_inblock_checkpoint_mid_block_granularity(tmp_path):
    """checkpoint_every=1 under an M=2 block writes a checkpoint for
    EVERY round — including the mid-block round that only an in-scan tap
    can see."""
    plan = ParticipationPlan(strategy="async", lag_dist="fixed", lag=1,
                             seed=0)
    fed = FederationConfig(**BASE2)
    ck = os.path.join(tmp_path, "ck_{step}.npz")
    f = Federation(fed, TINY)
    f.run_rounds(2, block_size=2, participation=plan,
                 checkpoint_path=ck, checkpoint_every=1)
    assert sorted(os.listdir(tmp_path)) == ["ck_1.npz", "ck_2.npz"]


# ----------------------------------------------------------------------
# tap hardening (ISSUE 7 satellite): a raising tap logs and drops
def test_raising_metric_tap_logs_and_drops(caplog):
    fed = FederationConfig(**BASE2)
    f = Federation(fed, TINY)
    seen = []

    def bad_tap(metrics):
        seen.append(metrics)
        raise RuntimeError("tap exploded")

    with caplog.at_level(logging.ERROR, logger="repro.engine"):
        recs = f.run_rounds(2, block_size=2, tap=bad_tap)
    assert len(recs) == 2                          # run completed
    assert len(seen) == 2                          # tap fired per round
    assert all(np.isfinite(r["task_loss"]) for r in recs)
    assert any("payload dropped" in r.message for r in caplog.records)


# ----------------------------------------------------------------------
# checkpoint corruption (ISSUE 7 satellite): clear errors, not tracebacks
def test_checkpoint_truncated_and_bitflipped(tmp_path):
    from repro.checkpoint import (CheckpointError, load_checkpoint,
                                  save_checkpoint)
    tree = {"a": jnp.arange(1024, dtype=jnp.float32),
            "b": jnp.ones((64, 64), jnp.float32)}
    path = os.path.join(tmp_path, "state.npz")
    save_checkpoint(path, tree, step=3)
    n_bytes = os.path.getsize(path)

    trunc = os.path.join(tmp_path, "trunc.npz")
    with open(path, "rb") as fh:
        blob = fh.read()
    with open(trunc, "wb") as fh:
        fh.write(blob[:n_bytes // 2])
    with pytest.raises(CheckpointError) as ei:
        load_checkpoint(trunc, tree)
    assert "trunc.npz" in str(ei.value)            # names the file
    assert str(n_bytes // 2) in str(ei.value)      # and the found size

    flip = os.path.join(tmp_path, "flip.npz")
    # flip bits in the middle of the archive (leaf data, not the central
    # directory) so np.load opens it but the member read fails CRC
    bad = bytearray(blob)
    for off in range(200, 2000, 80):
        bad[off] ^= 0xFF
    with open(flip, "wb") as fh:
        fh.write(bytes(bad))
    with pytest.raises((CheckpointError, ValueError)):
        load_checkpoint(flip, tree)

    # the intact file still round-trips
    restored, step = load_checkpoint(path, tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
