"""Resilient-serving tests: SLO shedding, deadlines, on-device output
guards, the chaos harness, and crash-recoverable decode state.

The load-bearing properties:

  - EXACTLY ONE TERMINAL STATE: under any fault schedule every request
    ends completed / shed / timed_out / failed, and the counts sum to
    the stream size;
  - NO GARBAGE: a token derived from poisoned logits is never emitted —
    every emitted stream is a PREFIX of the fault-free (greedy,
    deterministic) run's stream, and completed requests match it
    exactly;
  - BIT-IDENTICAL RESUME: kill-and-resume through the serve snapshot
    continues already-admitted slots exactly (tested at temperature > 0
    so the carried RNG key does real work).
"""
import dataclasses
import itertools

import jax
import pytest

from repro.checkpoint import CheckpointError, save_checkpoint
from repro.configs import get_config, reduced
from repro.models import transformer as T
from repro.serve import (FaultPlan, FifoScheduler, Request, ServeConfig,
                         ServeEngine, SimulatedCrash, poisson_requests,
                         state_counts)

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return get_config("fedmm-small").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    return cfg, T.init_params(KEY, cfg)


def _reqs(cfg, n, seed=3, prompt_len=8):
    return poisson_requests(n, 0.0, prompt_len=prompt_len,
                            vocab_size=cfg.vocab_size, seed=seed)


def _assert_accounting(recs, n):
    counts = state_counts(recs)
    assert sum(counts.get(s, 0) for s in
               ("completed", "shed", "timed_out", "failed")) == n, counts
    return counts


# ======================================================== scheduler
class TestSchedulerEdgeCases:
    def test_duplicate_rid_raises(self):
        reqs = [Request(rid=1, tokens=(1, 2)), Request(rid=1, tokens=(3,))]
        with pytest.raises(ValueError, match="duplicate"):
            FifoScheduler(reqs, 2)

    def test_zero_slots_never_admissible(self):
        sched = FifoScheduler([Request(rid=0, tokens=(1,))], 0)
        assert not sched.admissible(0.0)
        assert not sched.done          # queued work, nowhere to run it
        assert sched.next_ready() == 0.0

    def test_out_of_order_arrivals_admit_in_arrival_order(self):
        reqs = [Request(rid=0, tokens=(1,), arrival_s=0.5),
                Request(rid=1, tokens=(2,), arrival_s=0.0),
                Request(rid=2, tokens=(3,), arrival_s=0.25)]
        sched = FifoScheduler(reqs, 3)
        order = [sched.pop(1.0)[0].rid for _ in range(3)]
        assert order == [1, 2, 0]

    def test_release_already_free_slot_raises(self):
        sched = FifoScheduler([Request(rid=0, tokens=(1,))], 2)
        with pytest.raises(ValueError, match="already.*free"):
            sched.release(0, 0.0)
        req, slot = sched.pop(0.0)
        sched.release(slot, 1.0)
        with pytest.raises(ValueError, match="already.*free"):
            sched.release(slot, 2.0)     # double release = duplicated slot
        assert len(sched.free_slots) == 2
        with pytest.raises(ValueError, match="already.*free"):
            sched.requeue(slot, 2.0)

    def test_done_with_never_admitted_requests(self):
        reqs = [Request(rid=i, tokens=(1,), ttft_deadline_s=0.1)
                for i in range(3)]
        sched = FifoScheduler(reqs, 2)
        assert not sched.done
        assert sched.shed_expired(5.0) == 3    # all past their deadline
        assert sched.done
        assert all(r.state == "shed" for r in sched.records.values())

    def test_queue_cap_sheds_newest_arrivals(self):
        reqs = [Request(rid=i, tokens=(1,), arrival_s=0.01 * i)
                for i in range(5)]
        sched = FifoScheduler(reqs, 1, queue_cap=2)
        sched.pop(0.0)                          # rid 0 takes the slot
        assert sched.shed_expired(1.0) == 2     # cap bounds the WAITERS
        kept = [r.rid for r in sched.pending]
        assert kept == [1, 2]                   # oldest stay
        counts = state_counts(sched.records)
        assert counts["shed"] == 2

    def test_retry_lane_admits_before_pending(self):
        reqs = [Request(rid=i, tokens=(1,)) for i in range(3)]
        sched = FifoScheduler(reqs, 2)
        req, slot = sched.pop(0.0)
        assert req.rid == 0
        sched.requeue(slot, ready_s=1.0)
        # backoff not elapsed: the retry waits, but arrivals still flow
        req2, _ = sched.pop(0.5)
        assert req2.rid == 1
        # backoff elapsed: the ready retry (rid 0) beats pending rid 2
        req3, _ = sched.pop(2.0)
        assert req3.rid == 0 and sched.records[0].attempts == 2
        assert sched.next_ready() == 0.0        # rid 2 still queued


# ================================================== engine guards/SLOs
def test_engine_rejects_zero_slots(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="n_slots"):
        ServeEngine(params, cfg, ServeConfig(n_slots=0))


def test_overload_sheds_and_completes_rest(tiny):
    """Bounded queue + TTFT deadline: overload degrades to shed requests
    and bounded queueing, never an error, and completed requests still
    match the fault-free oracle."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=2, cache_len=64, block_steps=4,
                       max_new_tokens=24, queue_cap=1,
                       ttft_deadline_s=1e-4)
    reqs = _reqs(cfg, 6)
    clean = ServeEngine(params, cfg, dataclasses.replace(
        scfg, queue_cap=None, ttft_deadline_s=None)).serve(reqs)
    recs = ServeEngine(params, cfg, scfg).serve(reqs)
    counts = _assert_accounting(recs, 6)
    assert counts["shed"] >= 1
    assert counts["completed"] >= 2    # the first admissions always run
    for r in reqs:
        if recs[r.rid].state == "completed":
            assert recs[r.rid].tokens == clean[r.rid].tokens
        if recs[r.rid].state == "shed":
            assert recs[r.rid].tokens == []
            assert recs[r.rid].attempts == 0


def test_completion_deadline_times_out_slot(tiny):
    """A host delay pushes a request past its completion deadline; the
    watchdog cancels the slot at the next block boundary, the slot is
    reclaimed, and the partial stream is a prefix of the clean run."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=1, cache_len=64, block_steps=4,
                       max_new_tokens=24, deadline_s=0.05)
    reqs = _reqs(cfg, 2)
    clean = ServeEngine(params, cfg, dataclasses.replace(
        scfg, deadline_s=None)).serve(reqs)
    plan = FaultPlan(delay_blocks=(1, 7), delay_s=0.2)
    eng = ServeEngine(params, cfg, scfg)
    recs = eng.serve(reqs, fault_plan=plan)
    counts = _assert_accounting(recs, 2)
    assert counts["timed_out"] == 2       # both requests hit the delay
    for r in reqs:
        got = recs[r.rid].tokens
        assert got == clean[r.rid].tokens[:len(got)]
        assert 0 < len(got) < 24          # partial: started, then cut


def test_nan_guard_retries_to_clean_tokens(tiny):
    """NaN-poisoned decode steps trip the on-device guard; the poisoned
    token is never emitted, the request retries, and every completed
    stream is bit-identical to the fault-free run."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=3, cache_len=64, block_steps=4,
                       max_new_tokens=10, max_attempts=3)
    reqs = _reqs(cfg, 5, seed=11)
    clean = ServeEngine(params, cfg, scfg).serve(reqs)
    plan = FaultPlan(nan_steps=(3, 6), nan_slots=(0, 1))
    eng = ServeEngine(params, cfg, scfg)
    recs = eng.serve(reqs, fault_plan=plan)
    counts = _assert_accounting(recs, 5)
    assert counts["completed"] == 5
    assert eng.stats["faults_detected"] >= 1
    assert sum(recs[r.rid].retries for r in reqs) >= 1
    for r in reqs:
        assert recs[r.rid].tokens == clean[r.rid].tokens, r.rid


def test_poison_every_step_exhausts_retries_to_failed(tiny):
    """With every decode step poisoned the retry budget runs out and the
    request lands in the terminal ``failed`` state — never an emitted
    garbage token, never a livelock."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=1, cache_len=64, block_steps=4,
                       max_new_tokens=8, max_attempts=2)
    reqs = _reqs(cfg, 2, seed=5)
    plan = FaultPlan(nan_steps=tuple(range(512)))
    eng = ServeEngine(params, cfg, scfg)
    recs = eng.serve(reqs, fault_plan=plan)
    counts = _assert_accounting(recs, 2)
    assert counts["failed"] == 2
    for r in reqs:
        assert recs[r.rid].attempts == 2
        # only the (unpoisoned) prefill token ever made it out
        assert len(recs[r.rid].tokens) <= 1


def test_repetition_guard_catches_forced_token(tiny):
    """A finite-logit fault that forces one token to repeat slips past
    the non-finite guard but trips the runaway-repetition guard; the
    retry (past the forced window) completes clean."""
    cfg, params = tiny
    base = ServeConfig(n_slots=2, cache_len=64, block_steps=4,
                       max_new_tokens=40, max_attempts=3)
    reqs = _reqs(cfg, 2, seed=7)
    probe = ServeEngine(params, cfg, base).serve(reqs)
    longest = max(
        max(sum(1 for _ in g) for _, g in itertools.groupby(
            probe[r.rid].tokens)) for r in reqs)
    max_rep = longest + 2        # above any repeat the clean run emits
    if max_rep > 32:
        pytest.skip("degenerate model: clean run is one long repeat")
    # budget so the guard can trip (step 1 + max_rep) before exhaustion
    scfg = dataclasses.replace(base, max_repeat=max_rep,
                               max_new_tokens=max_rep + 6)
    clean = {rid: dataclasses.replace(
        rec, tokens=rec.tokens[:max_rep + 6])
        for rid, rec in probe.items()}
    # window ends AT the trip step (1 + max_rep): long enough to drive
    # rep_run over the limit, gone by the time the retry resumes
    plan = FaultPlan(force_steps=tuple(range(1, max_rep + 2)),
                     force_token=17)
    eng = ServeEngine(params, cfg, scfg)
    recs = eng.serve(reqs, fault_plan=plan)
    counts = _assert_accounting(recs, 2)
    assert eng.stats["faults_detected"] >= 1
    assert counts["completed"] == 2
    for r in reqs:
        assert recs[r.rid].tokens == clean[r.rid].tokens, r.rid
        assert recs[r.rid].retries >= 1


def test_stall_watchdog_reclaims_frozen_slot(tiny):
    """A silently-frozen slot (no tokens, not stopped) is reclaimed by
    the zero-progress watchdog and retried to a clean completion; with
    the watchdog off the freeze just delays the same result."""
    cfg, params = tiny
    base = ServeConfig(n_slots=2, cache_len=64, block_steps=4,
                       max_new_tokens=12, max_attempts=3)
    reqs = _reqs(cfg, 2, seed=9)
    clean = ServeEngine(params, cfg, base).serve(reqs)
    plan = FaultPlan(freeze_steps=tuple(range(4, 12)), freeze_slots=(0,))
    for stall_blocks in (2, 0):
        scfg = dataclasses.replace(base, stall_blocks=stall_blocks)
        eng = ServeEngine(params, cfg, scfg)
        recs = eng.serve(reqs, fault_plan=plan)
        counts = _assert_accounting(recs, 2)
        assert counts["completed"] == 2
        for r in reqs:
            assert recs[r.rid].tokens == clean[r.rid].tokens, \
                (stall_blocks, r.rid)
        if stall_blocks:
            assert eng.stats["stalls_detected"] >= 1


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "recurrentgemma-9b"])
def test_freeze_resumes_bit_identically_recurrent_state(arch):
    """A chaos-frozen slot that RESUMES (span shorter than the stall
    watchdog, or watchdog off) must continue bit-identically — which
    requires ``decode_step_slots`` to hold SSM / RG-LRU recurrent state
    for masked slots, not just the cache position (attention families
    get this for free from position gating; recurrent updates are not
    idempotent)."""
    cfg = reduced(get_config(arch))
    params = T.init_params(KEY, cfg)
    scfg = ServeConfig(n_slots=2, cache_len=96, block_steps=4,
                       max_new_tokens=12)
    reqs = _reqs(cfg, 2, seed=19)
    clean = ServeEngine(params, cfg, scfg).serve(reqs)
    plan = FaultPlan(freeze_steps=(3, 4, 5), freeze_slots=(0,))
    recs = ServeEngine(params, cfg, scfg).serve(reqs, fault_plan=plan)
    assert _assert_accounting(recs, 2)["completed"] == 2
    for r in reqs:
        assert recs[r.rid].tokens == clean[r.rid].tokens, r.rid


# ===================================================== snapshot/resume
def test_crash_resume_bit_identical_at_temperature(tiny, tmp_path):
    """Kill-and-resume through the serve snapshot: the resumed engine
    completes every unfinished request with tokens bit-identical to an
    uninterrupted run — at temperature > 0, so the carried RNG key (not
    greedy determinism) is what makes it exact."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=3, cache_len=64, block_steps=4,
                       max_new_tokens=12, temperature=0.7, seed=42)
    reqs = _reqs(cfg, 5, seed=13)
    want = ServeEngine(params, cfg, scfg).serve(reqs)
    snap = str(tmp_path / "serve.npz")
    plan = FaultPlan(crash_after_block=1)   # mid-decode for all slots
    eng = ServeEngine(params, cfg, scfg)
    with pytest.raises(SimulatedCrash):
        eng.serve(reqs, fault_plan=plan, snapshot_path=snap,
                  snapshot_every_blocks=1)
    partial = {rid: list(rec.tokens)
               for rid, rec in eng._sched.records.items()}
    assert any(rec.state == "running"
               for rec in eng._sched.records.values())
    eng2 = ServeEngine.resume(snap, params, cfg)
    recs = eng2.resume_serve()
    counts = _assert_accounting(recs, 5)
    assert counts["completed"] == 5
    for r in reqs:
        assert recs[r.rid].tokens == want[r.rid].tokens, r.rid
        # the crashed attempt's stream was a prefix of the final one
        got = [int(t) for t in partial[r.rid]]
        assert recs[r.rid].tokens[:len(got)] == got, r.rid


def test_resume_snapshot_taken_before_crash_block(tiny, tmp_path):
    """Snapshot cadence sparser than the crash point: the resumed run
    REPLAYS the lost block from the snapshot's device state and still
    matches the uninterrupted run exactly."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=2, cache_len=64, block_steps=4,
                       max_new_tokens=16, seed=1)
    reqs = _reqs(cfg, 3, seed=17)
    want = ServeEngine(params, cfg, scfg).serve(reqs)
    snap = str(tmp_path / "serve.npz")
    eng = ServeEngine(params, cfg, scfg)
    with pytest.raises(SimulatedCrash):
        eng.serve(reqs, fault_plan=FaultPlan(crash_after_block=2),
                  snapshot_path=snap, snapshot_every_blocks=2)
    eng2 = ServeEngine.resume(snap, params, cfg)
    recs = eng2.resume_serve()
    assert _assert_accounting(recs, 3)["completed"] == 3
    for r in reqs:
        assert recs[r.rid].tokens == want[r.rid].tokens, r.rid


def test_resume_rejects_corrupt_and_mismatched_snapshots(tiny, tmp_path):
    cfg, params = tiny
    scfg = ServeConfig(n_slots=2, cache_len=64, block_steps=4,
                       max_new_tokens=8)
    snap = str(tmp_path / "serve.npz")
    eng = ServeEngine(params, cfg, scfg)
    with pytest.raises(SimulatedCrash):
        eng.serve(_reqs(cfg, 3), fault_plan=FaultPlan(crash_after_block=1),
                  snapshot_path=snap, snapshot_every_blocks=1)
    # truncation -> CheckpointError with the path in the message
    with open(snap, "rb") as fh:
        blob = fh.read()
    trunc = str(tmp_path / "trunc.npz")
    with open(trunc, "wb") as fh:
        fh.write(blob[:len(blob) // 3])
    with pytest.raises(CheckpointError, match="trunc"):
        ServeEngine.resume(trunc, params, cfg)
    # a non-serve checkpoint -> ValueError, not a crash later
    other = str(tmp_path / "other.npz")
    save_checkpoint(other, {"x": jax.numpy.zeros((2,))}, meta={"a": 1})
    with pytest.raises(ValueError, match="not a serve snapshot"):
        ServeEngine.resume(other, params, cfg)
    # wrong model family -> ValueError before any device work
    ssm = get_config("falcon-mamba-7b")
    with pytest.raises(ValueError, match="family|model"):
        ServeEngine.resume(snap, params, ssm)


def test_chaos_composite_accounting(tiny):
    """The full chaos schedule at once — NaN poison, a freeze, host
    delays — over a stream with deadlines: every request ends in exactly
    one terminal state and no garbage token is ever emitted."""
    cfg, params = tiny
    scfg = ServeConfig(n_slots=3, cache_len=64, block_steps=4,
                       max_new_tokens=12, max_attempts=2,
                       stall_blocks=2, deadline_s=30.0)
    reqs = _reqs(cfg, 8, seed=23)
    clean = ServeEngine(params, cfg, dataclasses.replace(
        scfg, deadline_s=None)).serve(reqs)
    plan = FaultPlan(nan_steps=(5, 9), nan_slots=(0,),
                     freeze_steps=tuple(range(8, 16)), freeze_slots=(1,),
                     delay_blocks=(2,), delay_s=0.01)
    recs = ServeEngine(params, cfg, scfg).serve(reqs, fault_plan=plan)
    _assert_accounting(recs, 8)
    for r in reqs:
        got = recs[r.rid].tokens
        assert got == clean[r.rid].tokens[:len(got)], r.rid
