"""GeoLoRA / GeoDoRA parameter machinery (paper Eqs. 3-5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lora as L
from repro.models.common import (add_dora, add_lora, dora_column_norm,
                                 linear, make_linear)

KEY = jax.random.PRNGKey(0)


def test_lora_zero_b_is_identity():
    lin = make_linear(KEY, 12, 20, jnp.float32)
    lora = add_lora(jax.random.fold_in(KEY, 1), lin, 4, jnp.float32)
    x = jax.random.normal(KEY, (5, 12))
    np.testing.assert_allclose(np.asarray(linear(x, lin)),
                               np.asarray(linear(x, lora)), atol=1e-6)


def test_lora_matches_explicit_delta():
    lin = make_linear(KEY, 8, 10, jnp.float32)
    lora = add_lora(jax.random.fold_in(KEY, 2), lin, 3, jnp.float32)
    lora["lora_B"] = jax.random.normal(jax.random.fold_in(KEY, 3), (3, 10))
    x = jax.random.normal(KEY, (4, 8))
    want = x @ lin["w"] + (x @ lora["lora_A"]) @ lora["lora_B"]
    np.testing.assert_allclose(np.asarray(linear(x, lora)),
                               np.asarray(want), rtol=1e-5)


def test_dora_initial_decomposition_exact():
    """m initialised to ||W||_c with B=0 => DoRA output == base output."""
    lin = make_linear(KEY, 16, 12, jnp.float32)
    d = add_dora(add_lora(jax.random.fold_in(KEY, 4), lin, 4, jnp.float32))
    x = jax.random.normal(KEY, (6, 16))
    np.testing.assert_allclose(np.asarray(linear(x, lin)),
                               np.asarray(linear(x, d)), rtol=2e-5, atol=1e-5)


def test_dora_column_norm_matches_materialised():
    w = jax.random.normal(KEY, (10, 8))
    a = jax.random.normal(jax.random.fold_in(KEY, 5), (10, 3))
    b = jax.random.normal(jax.random.fold_in(KEY, 6), (3, 8))
    want = jnp.linalg.norm(w + a @ b, axis=0)
    got = dora_column_norm(w, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


def _toy_params():
    k1, k2 = jax.random.split(KEY)
    return {
        "blocks": {"attn": {"wq": make_linear(k1, 8, 8, jnp.float32),
                            "wo": make_linear(k2, 8, 8, jnp.float32)},
                   "mlp": {"up": make_linear(k1, 8, 16, jnp.float32)}},
        "embed": jax.random.normal(KEY, (32, 8)),
    }


def test_attach_targets_only():
    p = L.attach_lora(KEY, _toy_params(), L.LoRASpec(rank=2))
    assert "lora_A" in p["blocks"]["attn"]["wq"]
    assert "lora_A" in p["blocks"]["attn"]["wo"]
    assert "lora_A" not in p["blocks"]["mlp"]["up"]   # not a target


def test_attach_stacked_layers():
    lin = {"w": jax.random.normal(KEY, (4, 8, 10))}   # (L, d_in, d_out)
    p = L.attach_lora(KEY, {"wq": lin}, L.LoRASpec(rank=2, dora=True))
    assert p["wq"]["lora_A"].shape == (4, 8, 2)
    assert p["wq"]["lora_B"].shape == (4, 2, 10)
    assert p["wq"]["dora_m"].shape == (4, 10)


def test_partition_combine_roundtrip():
    p = L.attach_lora(KEY, _toy_params(), L.LoRASpec(rank=2, dora=True))
    mask = L.trainable_mask(p)
    train, frozen = L.partition(p, mask)
    back = L.combine(train, frozen)
    assert jax.tree.structure(back) == jax.tree.structure(p)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # only side-cars are trainable
    names = []
    def walk(node, name):
        if isinstance(node, dict):
            [walk(v, k) for k, v in node.items()]
        elif node is not None:
            names.append(name)
    walk(train, "")
    assert set(names) <= {"lora_B", "dora_m"}


def test_merge_lora_equals_runtime():
    p = L.attach_lora(KEY, _toy_params(), L.LoRASpec(rank=2))
    p["blocks"]["attn"]["wq"]["lora_B"] = \
        0.3 * jax.random.normal(KEY, (2, 8))
    x = jax.random.normal(KEY, (3, 8))
    live = linear(x, p["blocks"]["attn"]["wq"])
    merged = L.merge_lora(p)
    assert "lora_A" not in merged["blocks"]["attn"]["wq"]
    folded = linear(x, merged["blocks"]["attn"]["wq"])
    np.testing.assert_allclose(np.asarray(live), np.asarray(folded),
                               rtol=1e-5, atol=1e-6)


def test_merge_dora_equals_runtime():
    p = L.attach_lora(KEY, _toy_params(), L.LoRASpec(rank=2, dora=True))
    p["blocks"]["attn"]["wo"]["lora_B"] = \
        0.5 * jax.random.normal(KEY, (2, 8))
    p["blocks"]["attn"]["wo"]["dora_m"] = \
        1.0 + 0.1 * jax.random.normal(KEY, (8,))
    x = jax.random.normal(KEY, (3, 8))
    live = linear(x, p["blocks"]["attn"]["wo"])
    folded = linear(x, L.merge_lora(p)["blocks"]["attn"]["wo"])
    np.testing.assert_allclose(np.asarray(live), np.asarray(folded),
                               rtol=1e-4, atol=1e-5)


def test_param_counts():
    p = L.attach_lora(KEY, _toy_params(), L.LoRASpec(rank=2))
    mask = L.trainable_mask(p)
    train, _ = L.partition(p, mask)
    n_train = L.count_params(train)
    n_total = L.count_params(p)
    assert 0 < n_train < 0.2 * n_total
