"""Serving example: prefill a batch of requests, then batched decode with
arch-appropriate caches (ring-buffer SWA, MLA latents, SSM states).

    PYTHONPATH=src python examples/serve_decode.py --arch falcon-mamba-7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, s = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.image_embed_dim))
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.encoder_embed_dim))

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, bt: T.prefill(p, bt, cfg,
                                cache_len=s + cfg.n_image_tokens
                                + args.new_tokens + 8))(params, batch)
    print(f"prefill {b}x{s} [{cfg.family}] in {time.time()-t0:.1f}s "
          f"(cache leaves: {len(jax.tree.leaves(cache))})")

    decode = jax.jit(lambda p, c, t: T.decode_step(p, c, {"tokens": t}, cfg))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = (time.time() - t0) / args.new_tokens
    toks = jnp.concatenate(out, axis=1)
    print(f"decoded {args.new_tokens} tokens/seq @ {dt*1e3:.0f} ms/step "
          f"(greedy): {toks[0, :12].tolist()}...")
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    print("ok: finite logits, cache len =", int(cache["len"]))


if __name__ == "__main__":
    main()
