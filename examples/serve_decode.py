"""Serving example: continuous batching by default, the legacy batched
loop behind ``--legacy``.

Default path drives ``repro.serve.ServeEngine``: a slot-stacked cache
pool (ring-buffer SWA, MLA latents, SSM states — whatever the family
needs), requests admitted mid-decode into free slots, and decode fused
into M-step blocks (one jit dispatch + one host readback per M tokens
per slot, sampling and stop accounting on device).

    PYTHONPATH=src python examples/serve_decode.py --arch falcon-mamba-7b
    PYTHONPATH=src python examples/serve_decode.py --requests 12 --rate 8
    PYTHONPATH=src python examples/serve_decode.py --legacy

``--legacy`` runs the pre-engine loop on one fixed batch; its argmax is
folded into the jitted decode step (the host never touches per-token
logits) and the loop stays fully async until the final readback.

Failure modes and SLOs
----------------------
Every request ends in EXACTLY ONE terminal state, and each state maps
to one resilience mechanism:

* ``shed`` — admission control dropped it before it held a slot.
  ``--ttft-deadline`` sheds queued requests that can no longer get a
  first token in time; ``--queue-cap`` bounds how many arrived requests
  may wait (newest are rejected first).  Under overload, goodput
  degrades gracefully instead of every request going late together.
* ``timed_out`` — its completion deadline (``--deadline``, seconds
  after arrival) expired mid-decode.  The watchdog folds a cancel mask
  into the NEXT block dispatch (no extra dispatch: still one compiled
  call per M tokens) and reclaims the slot at the boundary.
* ``failed`` — a device fault exhausted its retry budget.  The fused
  block carries per-slot fault flags: non-finite logits and runaway
  repetition (``--max-repeat``) trip ON DEVICE and surface in the
  block's single readback; a frozen slot that stops emitting trips the
  host stall watchdog after ``--stall-blocks`` zero-progress blocks.
  Faulted requests requeue through a retry lane (``--max-attempts``,
  ``--retry-backoff``) and re-prefill from the prompt — a token derived
  from poisoned logits is never emitted.
* ``completed`` — and, greedy decoding being deterministic, its tokens
  are bit-identical to a fault-free run's.

``--chaos SEED`` turns on the deterministic fault harness
(:func:`repro.serve.seeded_plan`): NaN-poisoned decode steps, frozen
slots, and host-side block delays on a seeded schedule, so every
mechanism above can be watched firing.  ``--snapshot PATH
--snapshot-every N`` persists engine + scheduler state through the
checkpoint module every N blocks; after a crash, ``--resume PATH``
restores and finishes the unfinished requests (admitted slots resume
bit-identically — the RNG key rides the snapshot).

    python examples/serve_decode.py --requests 16 --rate 200 \
        --ttft-deadline 0.05 --queue-cap 8 --deadline 2.0
    python examples/serve_decode.py --chaos 7 --max-attempts 3 \
        --stall-blocks 2 --snapshot /tmp/serve.npz --snapshot-every 4
    python examples/serve_decode.py --resume /tmp/serve.npz
"""
import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import transformer as T
from repro.serve import (ServeConfig, ServeEngine, poisson_requests,
                         seeded_plan, state_counts)


def run_legacy(cfg, params, key, args):
    """One fixed batch, one token per jitted step — no admission, no
    early stop, head-of-line by construction."""
    b, s = args.batch, args.prompt_len
    # independent streams per input: never reuse one key across draws
    k_tok, k_img, k_aud = (jax.random.fold_in(key, i) for i in range(3))
    batch = {"tokens": jax.random.randint(k_tok, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k_img, (b, cfg.n_image_tokens, cfg.image_embed_dim))
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            k_aud, (b, cfg.encoder_seq_len, cfg.encoder_embed_dim))

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, bt: T.prefill(p, bt, cfg,
                                cache_len=s + cfg.n_image_tokens
                                + args.new_tokens + 8))(params, batch)
    print(f"prefill {b}x{s} [{cfg.family}] in {time.time()-t0:.1f}s "
          f"(cache leaves: {len(jax.tree.leaves(cache))})")

    # argmax INSIDE the jitted step: the host schedules M async steps and
    # reads tokens once at the end, instead of a logits readback per token
    @jax.jit
    def decode(p, c, t):
        lg, c = T.decode_step(p, c, {"tokens": t}, cfg)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32), c, lg

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens):
        tok, cache, logits = decode(params, cache, tok)
        out.append(tok)
    toks = jax.device_get(jnp.concatenate(out, axis=1))   # the one sync
    dt = (time.time() - t0) / args.new_tokens
    print(f"decoded {args.new_tokens} tokens/seq @ {dt*1e3:.1f} ms/step "
          f"(greedy): {toks[0, :12].tolist()}...")
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    print("ok: finite logits, cache len =", int(cache["len"]))


def run_engine(cfg, params, args):
    scfg = ServeConfig(n_slots=args.slots, cache_len=args.cache_len,
                       block_steps=args.block_steps,
                       max_new_tokens=args.new_tokens,
                       queue_cap=args.queue_cap,
                       ttft_deadline_s=args.ttft_deadline,
                       deadline_s=args.deadline,
                       max_attempts=args.max_attempts,
                       retry_backoff_s=args.retry_backoff,
                       stall_blocks=args.stall_blocks,
                       max_repeat=args.max_repeat)
    if args.resume:
        eng = ServeEngine.resume(args.resume, params, cfg)
        t0 = time.time()
        recs = eng.resume_serve()
        _report(cfg, eng, recs, time.time() - t0, args)
        return
    reqs = poisson_requests(args.requests, args.rate,
                            prompt_len=args.prompt_len,
                            vocab_size=cfg.vocab_size, seed=0)
    if cfg.family in ("vlm", "audio"):    # per-request modality inputs
        import dataclasses
        name, shape = (("image_embeds",
                        (cfg.n_image_tokens, cfg.image_embed_dim))
                       if cfg.family == "vlm" else
                       ("enc_embeds",
                        (cfg.encoder_seq_len, cfg.encoder_embed_dim)))
        reqs = [dataclasses.replace(r, extras=(
            (name, jax.random.normal(jax.random.fold_in(
                jax.random.PRNGKey(7), r.rid), shape)),))
                for r in reqs]
    plan = None
    if args.chaos >= 0:
        plan = seeded_plan(args.chaos, n_steps=args.requests
                           * args.new_tokens, n_slots=args.slots,
                           nan_rate=0.05, freeze_rate=0.02,
                           delay_rate=0.05, delay_s=0.002)
    eng = ServeEngine(params, cfg, scfg)
    t0 = time.time()
    recs = eng.serve(reqs, sync_ttft=args.rate > 0, fault_plan=plan,
                     snapshot_path=args.snapshot,
                     snapshot_every_blocks=args.snapshot_every)
    _report(cfg, eng, recs, time.time() - t0, args)


def _report(cfg, eng, recs, wall, args):
    toks = sum(len(r.tokens) for r in recs.values())
    print(f"[{cfg.family}] served {len(recs)} requests / {toks} tokens in "
          f"{wall:.1f}s ({toks/wall:.0f} tok/s) over {args.slots} slots")
    print(f"  dispatch structure: {eng.stats['block_dispatches']} block "
          f"dispatches, {eng.stats['block_syncs']} readbacks for "
          f"{eng.stats['block_tokens']} decoded tokens "
          f"(M={args.block_steps})")
    counts = state_counts(recs)
    print(f"  terminal states: {counts}; device faults "
          f"{eng.stats['faults_detected']}, stalls "
          f"{eng.stats['stalls_detected']}, retries "
          f"{sum(r.retries for r in recs.values())}, snapshots "
          f"{eng.stats['snapshot_writes']}")
    ttfts = [r.ttft_s for r in recs.values() if r.ttft_s is not None]
    if args.rate > 0 and ttfts:
        print(f"  ttft p50 {1e3*statistics.median(ttfts):.0f} ms over "
              f"Poisson arrivals at {args.rate:g} req/s")
    rid = min(recs)
    print(f"  request {rid}: {recs[rid].tokens[:12]}...")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--legacy", action="store_true",
                    help="pre-engine fixed-batch loop")
    ap.add_argument("--batch", type=int, default=4,
                    help="legacy loop batch size")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, req/s (0 = all at t=0)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-steps", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=192)
    slo = ap.add_argument_group("SLOs / resilience (see module docstring)")
    slo.add_argument("--ttft-deadline", type=float, default=None,
                     help="shed queued requests past this first-token "
                          "deadline (s after arrival)")
    slo.add_argument("--deadline", type=float, default=None,
                     help="cancel decoding requests past this completion "
                          "deadline (s after arrival)")
    slo.add_argument("--queue-cap", type=int, default=None,
                     help="bound on arrived requests allowed to wait")
    slo.add_argument("--max-attempts", type=int, default=2,
                     help="admissions per request before terminal failure")
    slo.add_argument("--retry-backoff", type=float, default=0.0,
                     help="seconds a faulted request waits before retry")
    slo.add_argument("--stall-blocks", type=int, default=0,
                     help="zero-progress blocks before the stall watchdog "
                          "reclaims a slot (0 = off)")
    slo.add_argument("--max-repeat", type=int, default=0,
                     help="on-device runaway-repetition guard threshold "
                          "(0 = off)")
    slo.add_argument("--chaos", type=int, default=-1, metavar="SEED",
                     help="enable the seeded fault-injection harness")
    slo.add_argument("--snapshot", default=None, metavar="PATH",
                     help="write crash-recoverable serve snapshots here")
    slo.add_argument("--snapshot-every", type=int, default=4,
                     help="blocks between snapshots")
    slo.add_argument("--resume", default=None, metavar="PATH",
                     help="restore a serve snapshot and finish its "
                          "unfinished requests")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    if args.legacy:
        run_legacy(cfg, params, jax.random.fold_in(key, 1), args)
    else:
        run_engine(cfg, params, args)


if __name__ == "__main__":
    main()
