"""Serving example: continuous batching by default, the legacy batched
loop behind ``--legacy``.

Default path drives ``repro.serve.ServeEngine``: a slot-stacked cache
pool (ring-buffer SWA, MLA latents, SSM states — whatever the family
needs), requests admitted mid-decode into free slots, and decode fused
into M-step blocks (one jit dispatch + one host readback per M tokens
per slot, sampling and stop accounting on device).

    PYTHONPATH=src python examples/serve_decode.py --arch falcon-mamba-7b
    PYTHONPATH=src python examples/serve_decode.py --requests 12 --rate 8
    PYTHONPATH=src python examples/serve_decode.py --legacy

``--legacy`` runs the pre-engine loop on one fixed batch; its argmax is
folded into the jitted decode step (the host never touches per-token
logits) and the loop stays fully async until the final readback.
"""
import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import transformer as T
from repro.serve import ServeConfig, ServeEngine, poisson_requests


def run_legacy(cfg, params, key, args):
    """One fixed batch, one token per jitted step — no admission, no
    early stop, head-of-line by construction."""
    b, s = args.batch, args.prompt_len
    # independent streams per input: never reuse one key across draws
    k_tok, k_img, k_aud = (jax.random.fold_in(key, i) for i in range(3))
    batch = {"tokens": jax.random.randint(k_tok, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k_img, (b, cfg.n_image_tokens, cfg.image_embed_dim))
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            k_aud, (b, cfg.encoder_seq_len, cfg.encoder_embed_dim))

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, bt: T.prefill(p, bt, cfg,
                                cache_len=s + cfg.n_image_tokens
                                + args.new_tokens + 8))(params, batch)
    print(f"prefill {b}x{s} [{cfg.family}] in {time.time()-t0:.1f}s "
          f"(cache leaves: {len(jax.tree.leaves(cache))})")

    # argmax INSIDE the jitted step: the host schedules M async steps and
    # reads tokens once at the end, instead of a logits readback per token
    @jax.jit
    def decode(p, c, t):
        lg, c = T.decode_step(p, c, {"tokens": t}, cfg)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32), c, lg

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens):
        tok, cache, logits = decode(params, cache, tok)
        out.append(tok)
    toks = jax.device_get(jnp.concatenate(out, axis=1))   # the one sync
    dt = (time.time() - t0) / args.new_tokens
    print(f"decoded {args.new_tokens} tokens/seq @ {dt*1e3:.1f} ms/step "
          f"(greedy): {toks[0, :12].tolist()}...")
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    print("ok: finite logits, cache len =", int(cache["len"]))


def run_engine(cfg, params, args):
    scfg = ServeConfig(n_slots=args.slots, cache_len=args.cache_len,
                       block_steps=args.block_steps,
                       max_new_tokens=args.new_tokens)
    reqs = poisson_requests(args.requests, args.rate,
                            prompt_len=args.prompt_len,
                            vocab_size=cfg.vocab_size, seed=0)
    if cfg.family in ("vlm", "audio"):    # per-request modality inputs
        import dataclasses
        name, shape = (("image_embeds",
                        (cfg.n_image_tokens, cfg.image_embed_dim))
                       if cfg.family == "vlm" else
                       ("enc_embeds",
                        (cfg.encoder_seq_len, cfg.encoder_embed_dim)))
        reqs = [dataclasses.replace(r, extras=(
            (name, jax.random.normal(jax.random.fold_in(
                jax.random.PRNGKey(7), r.rid), shape)),))
                for r in reqs]
    eng = ServeEngine(params, cfg, scfg)
    t0 = time.time()
    recs = eng.serve(reqs, sync_ttft=args.rate > 0)
    wall = time.time() - t0
    toks = sum(len(r.tokens) for r in recs.values())
    print(f"[{cfg.family}] served {len(reqs)} requests / {toks} tokens in "
          f"{wall:.1f}s ({toks/wall:.0f} tok/s) over {args.slots} slots")
    print(f"  dispatch structure: {eng.stats['block_dispatches']} block "
          f"dispatches, {eng.stats['block_syncs']} readbacks for "
          f"{eng.stats['block_tokens']} decoded tokens "
          f"(M={args.block_steps})")
    ttfts = [r.ttft_s for r in recs.values() if r.ttft_s is not None]
    if args.rate > 0 and ttfts:
        print(f"  ttft p50 {1e3*statistics.median(ttfts):.0f} ms over "
              f"Poisson arrivals at {args.rate:g} req/s")
    rid = min(recs)
    print(f"  request {rid}: {recs[rid].tokens[:12]}...")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--legacy", action="store_true",
                    help="pre-engine fixed-batch loop")
    ap.add_argument("--batch", type=int, default=4,
                    help="legacy loop batch size")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, req/s (0 = all at t=0)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-steps", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=192)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    if args.legacy:
        run_legacy(cfg, params, jax.random.fold_in(key, 1), args)
    else:
        run_engine(cfg, params, args)


if __name__ == "__main__":
    main()
