"""Quickstart: a 4-hospital federation with disjoint modalities on CPU.

Each node holds ONE private modality (image / text / genetics / tabular);
the public anchor set + Gram/CKA alignment pulls their latent geometries
together while GeoLoRA keeps the per-round uplink low-rank-sized.

Runs on the node-stacked engine by default: each round (all local epochs +
the server step) is ONE compiled call.  Pass --sequential for the per-node
reference loop the engine is equivalence-tested against.

    PYTHONPATH=src python examples/quickstart.py
"""
import argparse

from repro.configs import get_config
from repro.core.federation import (Federation, FederationConfig,
                                   SequentialFederation)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sequential", action="store_true",
                    help="run the per-node Python-loop reference instead "
                         "of the node-stacked engine")
    args = ap.parse_args()
    model = get_config("fedmm-small").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")
    fed = FederationConfig(
        n_nodes=4,
        modalities=("image", "text", "genetics", "tabular"),
        method="geodora",             # Eq. 5: direction shared, magnitude local
        aggregation="precision",      # Eq. 6: LAP-weighted server averaging
        rounds=4, local_steps=8, local_batch=32, lambda_geo=1.0)
    cls = SequentialFederation if args.sequential else Federation
    print(f"federation: {fed.n_nodes} nodes, one modality each, "
          f"method={fed.method}, engine={cls.__name__}")
    f = cls(fed, model)
    for r in range(fed.rounds):
        rec = f.run_round()
        print(f"round {r}: task={rec['task_loss']:.3f} "
              f"acc={rec['acc']:.2f} geo={rec['geo_loss']:.4f} "
              f"cross-modality CKA={rec['cross_node_cka']:.3f} "
              f"uplink={rec['uplink_bytes']/1e6:.3f}MB "
              f"({100*(1-rec['uplink_bytes']/rec['full_model_bytes']):.1f}% "
              f"below full-model FedAvg)")
    print("\nNodes never exchanged samples or activations — only "
          "B_k/m_k side-cars and 32x32 anchor Gram matrices.")


if __name__ == "__main__":
    main()
