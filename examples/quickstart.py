"""Quickstart: a 4-hospital federation with disjoint modalities on CPU.

Each node holds ONE private modality (image / text / genetics / tabular);
the public anchor set + Gram/CKA alignment pulls their latent geometries
together while GeoLoRA keeps the per-round uplink low-rank-sized.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.core.federation import Federation, FederationConfig


def main():
    model = get_config("fedmm-small").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")
    fed = FederationConfig(
        n_nodes=4,
        modalities=("image", "text", "genetics", "tabular"),
        method="geodora",             # Eq. 5: direction shared, magnitude local
        aggregation="precision",      # Eq. 6: LAP-weighted server averaging
        rounds=4, local_steps=8, local_batch=32, lambda_geo=1.0)
    print(f"federation: {fed.n_nodes} nodes, one modality each, "
          f"method={fed.method}")
    f = Federation(fed, model)
    for r in range(fed.rounds):
        rec = f.run_round()
        print(f"round {r}: task={rec['task_loss']:.3f} "
              f"acc={rec['acc']:.2f} geo={rec['geo_loss']:.4f} "
              f"cross-modality CKA={rec['cross_node_cka']:.3f} "
              f"uplink={rec['uplink_bytes']/1e6:.3f}MB "
              f"({100*(1-rec['uplink_bytes']/rec['full_model_bytes']):.1f}% "
              f"below full-model FedAvg)")
    print("\nNodes never exchanged samples or activations — only "
          "B_k/m_k side-cars and 32x32 anchor Gram matrices.")


if __name__ == "__main__":
    main()
