"""Probe: how does the CKA regulariser change cross-modal geometry?

Trains two tiny federations (lambda_geo=0 vs 1) on the same unpaired data
and prints the pairwise modality CKA matrix before/after — a direct view of
the paper's 'geometric Rosetta stone' at work.

    PYTHONPATH=src python examples/alignment_probe.py
"""
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cka as C
from repro.core.federation import Federation, FederationConfig


def run(lam):
    model = get_config("fedmm-small").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")
    fed = FederationConfig(n_nodes=4, rounds=3, local_steps=6,
                           local_batch=24, method="geolora",
                           lambda_geo=lam)
    f = Federation(fed, model)
    def gram_matrix():
        grams = []
        for i, node in enumerate(f.nodes):
            params = f.node_params(i)
            pooled = f._pooled(params, f.anchor_tokens[node["modality"]])
            grams.append(C.cosine_gram(pooled))
        return jnp.stack(grams)
    before = C.pairwise_cka(gram_matrix())
    f.run()
    after = C.pairwise_cka(gram_matrix())
    return before, after, f


def show(m, mods):
    print("      " + "  ".join(f"{x[:5]:>6s}" for x in mods))
    for i, row in enumerate(m):
        print(f"{mods[i][:5]:>6s}" + "  ".join(f"{float(v):6.3f}"
                                               for v in row))


def main():
    for lam in (0.0, 1.0):
        before, after, f = run(lam)
        mods = [n["modality"] for n in f.nodes]
        print(f"\n=== lambda_geo = {lam} ===")
        print("pairwise modality CKA before training:")
        show(before, mods)
        print("after 3 federated rounds:")
        show(after, mods)


if __name__ == "__main__":
    main()
