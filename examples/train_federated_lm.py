"""End-to-end driver: federated GeoDoRA fine-tuning of a language model.

Default runs a CPU-sized config for a few rounds; pass --full to train the
~100M fedmm-small for a few hundred steps (slow on CPU, sized for a real
accelerator), or --arch to pick any assigned architecture (reduced).

    PYTHONPATH=src python examples/train_federated_lm.py
    PYTHONPATH=src python examples/train_federated_lm.py --full

Partial participation
---------------------
Real cross-silo rounds rarely field every node.  The engine samples a
reporting cohort per round ON DEVICE (the sampler state rides the fused
round blocks and checkpoints), non-reporters carry their state through
untouched, and the server averages Grams/precisions/side-cars over exactly
the cohort:

    # 2-of-K uniformly sampled cohort per round (compute tracks the
    # cohort size, not K — the cohort rows are gathered compactly)
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation uniform --cohort-size 2

    # straggler simulation: each node drops out with p=0.25 per round
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation dropout --dropout-rate 0.25

    # poll unreliable (low LAP-precision) nodes less often
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation precision --cohort-size 2

``--participation full`` (default) is bit-identical to the
pre-participation driver.  Everything composes with ``--block-size M``
(or ``--block-size auto``) fused round blocks and ``--warmup-rounds N``
round-indexed LR schedules.

Failure modes and recovery
--------------------------
``--participation async`` switches to the buffered staleness-aware
protocol: every node trains against the LAST global it received, finished
reports land in a server-side buffer after a sampled lag, and each round
the server averages whatever is fresh enough.  The failure simulator runs
ON DEVICE from a carried RNG state, so the whole fault schedule rides the
fused round blocks and is reproducible from ``--participation-seed``:

    # straggling reports: geometric lag, capped at 4 rounds; reports
    # older than 2 rounds get zero weight (bounded staleness)
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation async --lag-dist geometric --lag-p 0.5 \
        --max-lag 4 --max-staleness 2 --staleness cutoff

    # soft staleness discounting instead: weight ~ (1 + lag)^-alpha
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation async --lag-dist fixed --lag 1 \
        --staleness poly --staleness-alpha 1.0

    # crash-and-rejoin: 10% of online nodes crash per round (their
    # in-flight report is lost), crashed nodes rejoin with p=0.5
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation async --crash-rate 0.1 --rejoin-rate 0.5

    # byzantine/fault injection: node 1's reports are corrupted to NaN
    # on device; the quarantine guard zeroes its contribution and bumps
    # its per-node counter (printed per round) — the run stays finite
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation async --poison-nodes 1 --quarantine-norm 1e6

Quarantine triggers on non-finite report values OR an update norm above
``--quarantine-norm``; quarantined reports are dropped before they touch
the buffer, so one bad node can never poison the global average.

Crash recovery composes with the fused blocks: the library's
``Federation.run_rounds(..., checkpoint_path=..., checkpoint_every=N)``
streams checkpoints from INSIDE a compiled M-round block (an io_callback
state tap every N rounds), so a preempted run restores bit-identically
losing at most N rounds — see tests/test_async.py for the
kill-and-resume proof.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 25 rounds x 8 local steps")
    ap.add_argument("--arch", default="fedmm-small")
    ap.add_argument("--participation", default="full",
                    choices=["full", "uniform", "precision", "dropout",
                             "async"])
    ap.add_argument("--cohort-size", type=int, default=None)
    ap.add_argument("--dropout-rate", type=float, default=0.25)
    # anything else (--block-size, --warmup-rounds, and the async flags
    # --lag-dist/--lag/--lag-p/--max-lag/--max-staleness/--staleness/
    # --staleness-alpha/--crash-rate/--rejoin-rate/--transient-rate/
    # --quarantine-norm/--poison-nodes) passes through to the underlying
    # repro.launch.train driver
    args, extra = ap.parse_known_args()
    part = ["--participation", args.participation,
            "--dropout-rate", str(args.dropout_rate)] + extra
    if args.cohort_size is not None:
        part += ["--cohort-size", str(args.cohort_size)]
    if args.full:
        train_main(["--arch", args.arch, "--rounds", "25",
                    "--local-steps", "8", "--batch", "8", "--seq", "512",
                    "--method", "geodora"] + part)
    else:
        train_main(["--arch", args.arch, "--tiny", "--rounds", "3",
                    "--local-steps", "4", "--batch", "4", "--seq", "128",
                    "--method", "geodora"] + part)


if __name__ == "__main__":
    main()
