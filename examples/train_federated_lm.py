"""End-to-end driver: federated GeoDoRA fine-tuning of a language model.

Default runs a CPU-sized config for a few rounds; pass --full to train the
~100M fedmm-small for a few hundred steps (slow on CPU, sized for a real
accelerator), or --arch to pick any assigned architecture (reduced).

    PYTHONPATH=src python examples/train_federated_lm.py
    PYTHONPATH=src python examples/train_federated_lm.py --full

Partial participation
---------------------
Real cross-silo rounds rarely field every node.  The engine samples a
reporting cohort per round ON DEVICE (the sampler state rides the fused
round blocks and checkpoints), non-reporters carry their state through
untouched, and the server averages Grams/precisions/side-cars over exactly
the cohort:

    # 2-of-K uniformly sampled cohort per round (compute tracks the
    # cohort size, not K — the cohort rows are gathered compactly)
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation uniform --cohort-size 2

    # straggler simulation: each node drops out with p=0.25 per round
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation dropout --dropout-rate 0.25

    # poll unreliable (low LAP-precision) nodes less often
    PYTHONPATH=src python examples/train_federated_lm.py \
        --participation precision --cohort-size 2

``--participation full`` (default) is bit-identical to the
pre-participation driver.  Everything composes with ``--block-size M``
(or ``--block-size auto``) fused round blocks and ``--warmup-rounds N``
round-indexed LR schedules.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 25 rounds x 8 local steps")
    ap.add_argument("--arch", default="fedmm-small")
    ap.add_argument("--participation", default="full",
                    choices=["full", "uniform", "precision", "dropout"])
    ap.add_argument("--cohort-size", type=int, default=None)
    ap.add_argument("--dropout-rate", type=float, default=0.25)
    # anything else (--block-size, --warmup-rounds, ...) passes through to
    # the underlying repro.launch.train driver
    args, extra = ap.parse_known_args()
    part = ["--participation", args.participation,
            "--dropout-rate", str(args.dropout_rate)] + extra
    if args.cohort_size is not None:
        part += ["--cohort-size", str(args.cohort_size)]
    if args.full:
        train_main(["--arch", args.arch, "--rounds", "25",
                    "--local-steps", "8", "--batch", "8", "--seq", "512",
                    "--method", "geodora"] + part)
    else:
        train_main(["--arch", args.arch, "--tiny", "--rounds", "3",
                    "--local-steps", "4", "--batch", "4", "--seq", "128",
                    "--method", "geodora"] + part)


if __name__ == "__main__":
    main()
