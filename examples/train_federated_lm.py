"""End-to-end driver: federated GeoDoRA fine-tuning of a language model.

Default runs a CPU-sized config for a few rounds; pass --full to train the
~100M fedmm-small for a few hundred steps (slow on CPU, sized for a real
accelerator), or --arch to pick any assigned architecture (reduced).

    PYTHONPATH=src python examples/train_federated_lm.py
    PYTHONPATH=src python examples/train_federated_lm.py --full
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 25 rounds x 8 local steps")
    ap.add_argument("--arch", default="fedmm-small")
    args = ap.parse_args()
    if args.full:
        train_main(["--arch", args.arch, "--rounds", "25",
                    "--local-steps", "8", "--batch", "8", "--seq", "512",
                    "--method", "geodora"])
    else:
        train_main(["--arch", args.arch, "--tiny", "--rounds", "3",
                    "--local-steps", "4", "--batch", "4", "--seq", "128",
                    "--method", "geodora"])


if __name__ == "__main__":
    main()
